"""The paper's core thesis, demonstrated at pod scale: the SAME model gets
DIFFERENT optimal compression policies on DIFFERENT hardware targets.

Target A: single v5e chip, batch-1 decode (edge-serving analogue).
Target B: 16-chip TP slice of a pod, batch-128 decode_32k (pod serving) —
          KV-cache traffic dominates, so the joint agent should shift
          from weight-int4 toward cache-friendly pruning.

    PYTHONPATH=src:. python examples/hardware_specific_policies.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks.common import get_lm_testbed
from repro.core.compress import CompressibleLM
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import CompressionSearch, SearchConfig


def run_target(name, ctx, episodes=30):
    cfg, params, val, _ = get_lm_testbed()
    cm = CompressibleLM(cfg, params)
    scfg = SearchConfig(methods="pq", episodes=episodes,
                        reward=RewardConfig(target_ratio=0.5),
                        ddpg=DDPGConfig(warmup_episodes=8,
                                        updates_per_episode=16,
                                        batch_size=64))
    search = CompressionSearch(cm, val, scfg, ctx)
    res = search.run(verbose=False)
    best = res.best_under_budget(0.05) or res.best
    bits = [c.w_bits for s, c in zip(search.specs, best.policy.cmps)
            if s.quantizable]
    keeps = [c.keep / s.prune_dim for s, c in
             zip(search.specs, best.policy.cmps) if s.prune_dim]
    print(f"[{name}] acc={best.accuracy:.3f} "
          f"lat={best.latency_s / res.ref_latency_s:.2%} "
          f"mean_w_bits={np.mean(bits):.1f} mean_keep={np.mean(keeps):.2f}")
    return best


def main():
    edge = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)
    pod = LatencyContext(tokens=128, seq_ctx=32_768, mode="decode",
                         batch=128, chips=16, tp=16)
    a = run_target("edge: 1 chip, batch-1 decode", edge)
    b = run_target("pod: 16-chip TP, batch-128 decode-32k", pod)
    same = sum(ca.mode == cb.mode and ca.keep == cb.keep
               for ca, cb in zip(a.policy.cmps, b.policy.cmps))
    print(f"\npolicies agree on {same}/{len(a.policy.cmps)} layers — "
          "hardware target changes the optimal policy (paper §Introduction)")


if __name__ == "__main__":
    main()
