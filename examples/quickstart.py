"""Quickstart: Galen joint pruning+quantization search on a small LM.

    PYTHONPATH=src:. python examples/quickstart.py

Trains (or loads) the testbed LM, runs a short joint search against the
TPU-v5e latency oracle with a 50% latency budget, prints the best policy.
Runtime: ~3-5 min on one CPU core (first run trains the testbed).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import SERVE_CTX, get_lm_testbed
from benchmarks.policy_analysis import render_policy
from repro.core.compress import CompressibleLM
from repro.core.ddpg import DDPGConfig
from repro.core.reward import RewardConfig
from repro.core.search import CompressionSearch, SearchConfig


def main():
    cfg, params, val, clean_acc = get_lm_testbed()
    print(f"testbed LM: {cfg.num_layers}L d={cfg.d_model} "
          f"clean accuracy {clean_acc:.3f}")
    cm = CompressibleLM(cfg, params)
    scfg = SearchConfig(
        methods="pq", episodes=30,
        reward=RewardConfig(target_ratio=0.5, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=8, updates_per_episode=16,
                        batch_size=64, buffer_size=2000))
    print("running sensitivity analysis + 30 episodes ...")
    search = CompressionSearch(cm, val, scfg, SERVE_CTX)
    res = search.run(verbose=True)
    best = res.best_under_budget(0.05) or res.best
    print(f"\nbest policy: accuracy {best.accuracy:.3f} "
          f"(clean {res.ref_accuracy:.3f}), latency "
          f"{best.latency_s / res.ref_latency_s:.2%} of uncompressed, "
          f"MACs {best.macs_frac:.2%}")
    for line in render_policy(search.specs, best.policy):
        print("  " + line)


if __name__ == "__main__":
    main()
