"""End-to-end driver: TRAIN a model with the production trainer
(checkpoint + restart safe), COMPRESS it with the Galen joint agent, QAT-
RETRAIN under the found policy, then SERVE it under sustained batched
requests.

    PYTHONPATH=src:. python examples/train_compress_serve.py \
        [--steps 200] [--episodes 30]

This is the full paper pipeline on one CPU core (~10 min). ``--steps 2``
runs the whole thing as a CI smoke: every stage scales down with the
step budget (tiny search, 4 QAT steps, short decode) but the SAME code
paths execute. On a TPU pod the same code runs with --arch
<assigned-arch> full configs (see repro/launch/train.py).
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.compress import CompressibleLM
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import CompressionSearch, SearchConfig
from repro.data.pipeline import DataConfig, ShardedTokenDataset, bigram_lm
from repro.launch.serve import decode_loop, sustained_throughput
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--episodes", type=int, default=None,
                    help="search episodes (default: 30, or 6 in smoke)")
    ap.add_argument("--target", type=float, default=0.5)
    args = ap.parse_args()

    # --steps 2 is the CI smoke: every stage shrinks with the budget
    smoke = args.steps <= 10
    episodes = args.episodes if args.episodes is not None \
        else (6 if smoke else 30)
    qat_steps = 4 if smoke else 60
    serve_steps = 8 if smoke else 24
    dcfg = DDPGConfig(warmup_episodes=2 if smoke else 8,
                      updates_per_episode=2 if smoke else 16,
                      batch_size=16 if smoke else 64)

    cfg = ArchConfig(name="e2e-lm", num_layers=4, d_model=128, num_heads=8,
                     num_kv_heads=4, head_dim=16, d_ff=512, vocab_size=256)

    # ---- 1. TRAIN with the production trainer (ckpt + resume) ----
    ckpt_dir = tempfile.mkdtemp(prefix="galen_e2e_")
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=min(20, args.steps),
                              total_steps=args.steps, weight_decay=0.0)
    tcfg = TrainerConfig(total_steps=args.steps,
                         ckpt_every=max(1, args.steps // 2),
                         log_every=max(1, args.steps // 4),
                         ckpt_dir=ckpt_dir)
    trainer = Trainer(cfg, opt_cfg, tcfg, seed=0)
    trainer.maybe_restore()
    ds = ShardedTokenDataset(f"synthetic://{cfg.vocab_size}",
                             DataConfig(seq_len=48, global_batch=16))
    it = (ds.batch_at(s) for s in range(trainer.step, args.steps + 1))
    hist = trainer.fit(it)
    print(f"[1/4] trained {args.steps} steps; loss "
          f"{hist[-1]['loss']:.3f}; checkpoints in {ckpt_dir}")

    # ---- 2. COMPRESS: joint Galen search against the v5e oracle ----
    cm = CompressibleLM(cfg, trainer.params)
    val = ds.batch_at(10_001)
    val = {"tokens": jnp.asarray(val["tokens"])}
    ctx = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)
    scfg = SearchConfig(methods="pq", episodes=episodes,
                        reward=RewardConfig(target_ratio=args.target),
                        ddpg=dcfg)
    search = CompressionSearch(cm, val, scfg, ctx)
    res = search.run(verbose=False)
    best = res.best_under_budget(0.05) or res.best
    print(f"[2/4] search: accuracy {best.accuracy:.3f} "
          f"(clean {res.ref_accuracy:.3f}) at "
          f"{best.latency_s / res.ref_latency_s:.1%} latency")

    # ---- 3. QAT RETRAIN under the found policy (paper: 30 epochs) ----
    cspec = cm.build_cspec(best.policy)
    params = trainer.params
    opt = adamw_init(params, opt_cfg)
    qat_step = jax.jit(make_train_step(cfg, opt_cfg, cspec=cspec))
    for s in range(qat_steps):
        params, opt, m = qat_step(params, opt, ds.batch_at(20_000 + s))
    cm2 = CompressibleLM(cfg, params)
    acc_rt = float(cm2.accuracy(val, cm2.build_cspec(best.policy)))
    print(f"[3/4] QAT retrain: accuracy {best.accuracy:.3f} -> {acc_rt:.3f}")

    # ---- 4. SERVE the compressed model under sustained requests ----
    cspec_final = cm2.build_cspec(best.policy)
    tokens, dt = decode_loop(cfg, params, batch=4, steps=serve_steps,
                             max_len=128, cspec=cspec_final)
    tok_s, times = sustained_throughput(
        cfg, params, batch=4, steps=serve_steps, max_len=128,
        cspec=cspec_final, requests=2 if smoke else 4)
    print(f"[4/4] served 4x{serve_steps} tokens in {dt:.2f}s; sustained "
          f"{tok_s:.1f} tok/s over batched requests "
          f"(per-request {min(times):.3f}-{max(times):.3f}s)")
    print("done.")


if __name__ == "__main__":
    main()
