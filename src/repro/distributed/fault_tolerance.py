"""Fault tolerance & straggler mitigation for 1000+-node runs (DESIGN §4).

What runs here (single-host simulatable, tested in tests/):
* ``StepMonitor`` — per-step wall-time tracking; flags stragglers when a
  step exceeds ``straggler_factor`` × the trailing median; raises
  ``StepTimeout`` on hard hangs so the launcher can checkpoint-restart.
* ``HealthLedger`` — host heartbeat bookkeeping; decides when to trigger an
  elastic re-mesh (drop failed hosts, shrink the data axis) and computes
  the replacement mesh shape.
* ``elastic_data_axis`` — largest data-parallel axis that the surviving
  host count supports (model axis is never shrunk — TP degree is a model
  property; data/pod axes absorb failures).

What the real cluster adds (documented, not simulatable offline): the
launcher (launch/train.py) wraps fit() in a retry loop — on XLA
DataLoss/heartbeat loss it reloads the latest atomic checkpoint (written
by checkpoint/checkpointing.py) with shardings for the surviving mesh and
continues; the data pipeline being a pure function of (seed, step) makes
the resume bit-exact.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class StepTimeout(RuntimeError):
    pass


@dataclass
class FaultToleranceConfig:
    straggler_factor: float = 2.0      # step > factor*median => straggler
    straggler_window: int = 50
    hard_timeout_s: float = 0.0        # 0 = disabled
    heartbeat_timeout_s: float = 60.0


class StepMonitor:
    def __init__(self, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self.times: collections.deque = collections.deque(
            maxlen=cfg.straggler_window)
        self.stragglers: List[int] = []
        self.total_recorded = 0

    def record(self, step: int, dt: float):
        self.total_recorded += 1
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.cfg.straggler_factor * med:
                self.stragglers.append(step)
            if self.cfg.hard_timeout_s and dt > self.cfg.hard_timeout_s:
                raise StepTimeout(f"step {step} took {dt:.1f}s")
        self.times.append(dt)

    @property
    def median_step_s(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]

    def summary(self) -> dict:
        """JSON-able digest for fleet logs/manifests: epochs recorded, the
        trailing median, and which epochs were flagged as stragglers."""
        return {"recorded": self.total_recorded,
                "median_step_s": self.median_step_s,
                "stragglers": list(self.stragglers)}


class HealthLedger:
    """Track host heartbeats; propose elastic re-mesh on failure."""

    def __init__(self, num_hosts: int, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self.last_seen: Dict[int, float] = {h: time.time()
                                            for h in range(num_hosts)}
        self.excluded: set = set()

    def heartbeat(self, host: int, now: Optional[float] = None):
        self.last_seen[host] = now if now is not None else time.time()

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items()
                if h not in self.excluded
                and now - t > self.cfg.heartbeat_timeout_s]

    def exclude(self, hosts) -> None:
        self.excluded.update(hosts)

    @property
    def healthy(self) -> List[int]:
        return [h for h in self.last_seen if h not in self.excluded]


def elastic_data_axis(healthy_hosts: int, chips_per_host: int,
                      model_axis: int) -> int:
    """Largest power-of-two data axis the surviving chips support."""
    chips = healthy_hosts * chips_per_host
    data = max(1, chips // model_axis)
    p = 1
    while p * 2 <= data:
        p *= 2
    return p
