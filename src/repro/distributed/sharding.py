"""Logical-axis sharding rules (MaxText-style) for all assigned archs.

Two pieces:

* ``axis_rules`` context — models annotate activations with logical axes via
  ``shard(x, "batch", "seq", "embed")``; the active context maps logical axes
  to mesh axes and inserts ``with_sharding_constraint``. Outside a context the
  helper is a no-op, so single-device smoke tests never touch device state.

* ``param_shardings(arch, params)`` — path-regex table mapping every weight
  leaf to a PartitionSpec implementing DP/FSDP over ``data`` (+``pod``) and
  TP/EP over ``model``, with a divisibility guard that drops a mesh axis
  whenever a dim does not divide evenly (keeps one rule-set valid for full
  and reduced smoke configs alike).
"""
from __future__ import annotations

import re
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()

# Logical axis -> mesh axes. "pod" is prepended to batch when present.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": (),                 # sequence kept unsharded (SP optional, see below)
    "seq_sp": ("model",),      # sequence-parallel variant (norm/residual path)
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "fsdp": ("data",),         # weight-only axis
    "state": (),
    "layers": (),
}


class axis_rules:
    """Context manager activating a mesh + logical-axis rules."""

    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES if rules is None else rules)
        # Drop mesh axes that do not exist (e.g. "pod" on the single-pod mesh).
        names = set(mesh.axis_names)
        self.rules = {
            k: tuple(a for a in v if a in names) for k, v in self.rules.items()
        }

    def __enter__(self):
        stack = getattr(_ctx, "stack", [])
        stack.append(self)
        _ctx.stack = stack
        return self

    def __exit__(self, *exc):
        _ctx.stack.pop()
        return False


def current_rules() -> Optional["axis_rules"]:
    stack = getattr(_ctx, "stack", [])
    return stack[-1] if stack else None


def current_axis_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 w/o context).
    Used e.g. by the MoE layer to pick its shard-local dispatch grouping."""
    ctx = current_rules()
    if ctx is None:
        return 1
    size = 1
    for a in ctx.rules.get(logical, ()):
        size *= ctx.mesh.shape[a]
    return size


def _spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
              ctx: "axis_rules") -> P:
    mesh = ctx.mesh
    parts, used = [], set()
    for dim, name in zip(shape, logical):
        axes = ctx.rules.get(name, ()) if name else ()
        axes = tuple(a for a in axes if a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 1 and dim % size == 0:
            parts.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            parts.append(None)
    return P(*parts)


def shard(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """Annotate activation ``x`` with logical axes (no-op w/o active rules)."""
    ctx = current_rules()
    if ctx is None or x.ndim != len(logical):
        return x
    spec = _spec_for(x.shape, logical, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter shardings: path-regex -> logical axes per dim.
# Weight layout convention is [in, out]; stacked scan layers prepend "layers".
# FSDP ("fsdp" -> data axis) shards the non-TP weight axis, ZeRO-3 style;
# optimizer state inherits these specs (see repro/optim).
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding: vocab-TP only. FSDP on d would make the
    # logits matmul contract over the FSDP axis -> all-reduce of the FULL
    # logits tensor (8.6 GB/dev for mixtral train) — §Perf iteration A1.
    (r"(^|/)embed$",          ("vocab", None)),
    (r"unembed$",             (None, "vocab")),
    # attention (linear params nest as .../w and .../b)
    (r"attn/w(q|k|v)/w$",     ("fsdp", "heads")),
    (r"attn/w(q|k|v)/b$",     ("heads",)),
    (r"attn/wo/w$",           ("heads", "fsdp")),
    (r"attn/wo/b$",           (None,)),
    # dense mlp
    (r"mlp/w_(up|gate)/w$",   ("fsdp", "ff")),
    (r"mlp/w_down/w$",        ("ff", "fsdp")),
    # moe — "ep" archs shard experts over model, "tp" archs shard ff
    (r"moe/router$",          ("fsdp", None)),
    (r"moe/w_(up|gate)$",     ("experts", "fsdp", "ff")),
    (r"moe/w_down$",          ("experts", "ff", "fsdp")),
    (r"moe/dense_w_(up|gate)$", ("fsdp", "ff")),
    (r"moe/dense_w_down$",    ("ff", "fsdp")),
    # ssm
    (r"ssm/in_proj$",         ("fsdp", "heads")),
    (r"ssm/out_proj$",        ("heads", "fsdp")),
    (r"ssm/conv_w$",          (None, "heads")),
    (r"ssm/(A_log|D|dt_bias)$", ("heads",)),
    # rg-lru
    (r"rglru/w_(x|y)$",       ("fsdp", "ff")),
    (r"rglru/w_out$",         ("ff", "fsdp")),
    (r"rglru/(conv_w)$",      (None, "ff")),
    (r"rglru/(a_param|w_a|b_a|w_i|b_i)$", ("ff",)),
    # norms / scalars — replicated
    (r".*",                   None),
]


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def logical_axes_for_path(path: str, ndim: int, stacked: bool) -> tuple:
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            if axes is None:
                return (None,) * ndim
            axes = tuple(axes)
            if stacked and ndim == len(axes) + 1:
                axes = ("layers",) + axes
            if len(axes) != ndim:  # bias under a matched matmul rule, etc.
                return (None,) * ndim
            return axes
    return (None,) * ndim


def _container_axes(p: str, ndim: int, stacked: bool) -> tuple:
    """Logical axes for a leaf, understanding deploy-quantized containers
    (core/deploy.py): ``.../w_q|w_p`` shard like the dense weight,
    ``.../w_scale`` keeps only the out-channel axis."""
    leafname = p.split("/")[-1]
    if leafname in ("w_q", "w_p", "w_scale"):
        parent = p.rsplit("/", 1)[0]
        for cand in (parent + "/w", parent):
            axes = logical_axes_for_path(cand, ndim, stacked)
            if any(a is not None for a in axes):
                break
        if leafname == "w_scale":
            axes = (None,) * (ndim - 1) + (axes[-1],)
        return axes
    return logical_axes_for_path(p, ndim, stacked)


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None,
                    scanned: bool = True):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    ctx = axis_rules(mesh, rules)

    def leaf(path, x):
        p = _path_str(path)
        stacked = scanned and p.startswith("blocks")
        axes = _container_axes(p, x.ndim, stacked)
        return NamedSharding(mesh, _spec_for(x.shape, axes, ctx))

    return jax.tree_util.tree_map_with_path(leaf, params)


def cache_shardings(cache_shape, mesh: Mesh, rules: Optional[dict] = None):
    """Decode-cache shardings: batch over (pod, data); the model-axis
    placement is SIZE-DEPENDENT (§Perf C2):

      1. head (TP) sharding when kv_heads divides the model axis;
      2. else REPLICATE over model when the per-device copy is small
         (< threshold) — dynamic-update-slice then stays fully local;
      3. else context-parallel: shard the cache LENGTH dim (fits big
         caches; costs per-step DUS/softmax-combine collectives).
    """
    ctx = axis_rules(mesh, rules)

    model_size = 1
    for a in ctx.rules.get("kv_heads", ()):
        model_size *= mesh.shape[a]
    batch_size = 1
    for a in ctx.rules.get("batch", ()):
        batch_size *= mesh.shape[a]

    def _kv_policy(x, kv, tail_dims):
        if model_size > 1 and kv % model_size == 0:
            return "heads"
        elems = 1
        for d in x.shape[-tail_dims:]:   # per-LAYER size (drop scan stack)
            elems *= d
        per_dev = elems * x.dtype.itemsize / max(1, batch_size)
        return "replicate" if per_dev <= CACHE_REPLICATE_THRESHOLD \
            else "length"

    def leaf(path, x):
        name = _path_str(path).split("/")[-1]
        nd = x.ndim
        if name in ("k_s", "v_s"):
            policy = _kv_policy(x, x.shape[-1], 3)
            axes = {"heads": ("batch", None, "kv_heads"),
                    "replicate": ("batch", None, None),
                    "length": ("batch", "seq_sp", None)}[policy]
        elif name in ("k", "v"):
            policy = _kv_policy(x, x.shape[-2], 4)
            axes = {"heads": ("batch", None, "kv_heads", None),
                    "replicate": ("batch", None, None, None),
                    "length": ("batch", "seq_sp", None, None)}[policy]
        elif name == "state":
            axes = ("batch", "heads", None, None) if nd >= 4 \
                else ("batch", "ff")
        elif name == "conv":
            axes = ("batch", None, "ff")
        else:
            axes = (None,) * nd
        if nd == len(axes) + 1:          # scan-stacked leading layer dim
            axes = ("layers",) + axes
        if len(axes) < nd:
            axes = axes + (None,) * (nd - len(axes))
        return NamedSharding(mesh, _spec_for(x.shape, axes[:nd], ctx))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


# §Perf C2 (REFUTED): replicating small caches over the model axis was
# hypothesized to eliminate DUS collectives; measured 26x WORSE (XLA moves
# the full per-device cache through collectives each step when the written
# k/v slice arrives model-sharded). Length-sharding stays the fallback.
CACHE_REPLICATE_THRESHOLD = 0   # bytes; 0 = never replicate


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Member-axis rules (population search fleets).
#
# A PopulationSearch dispatch stacks every member's epoch carry (AgentState,
# DeviceReplay ring, rollout PRNG key, ...) along a new leading MEMBER axis
# and runs jit(vmap(epoch)). Placing those stacks with P("data") along the
# member axis makes the same program execute one member per mesh device
# (members beyond the data extent round-robin). Per-member math never mixes
# rows, so no collectives are introduced — the partitioner slices the batch.
# ---------------------------------------------------------------------------


def member_sharding(mesh: Mesh, ndim: int):
    """Shard the leading (member) axis over ``data``; rest replicated."""
    if ndim == 0:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P("data", *([None] * (ndim - 1))))


def population_shardings(tree, mesh: Mesh):
    """Member-axis NamedSharding pytree matching a STACKED population tree
    (every leaf's dim 0 is the member axis). Works on ShapeDtypeStructs.
    Leaves whose member dim does not divide the mesh ``data`` extent are
    replicated instead (callers normally pad the stack first — see
    ``pad_members``)."""
    data = mesh.shape["data"]

    def leaf(x):
        nd = jnp.ndim(x)
        if nd == 0 or (jnp.shape(x)[0] % data) != 0:
            return NamedSharding(mesh, P(*([None] * nd)))
        return member_sharding(mesh, nd)

    return jax.tree.map(leaf, tree)


def pad_members(trees: list, data: int) -> list:
    """Pad a list of per-member pytrees up to a multiple of the mesh data
    extent by repeating the last member (its outputs are discarded), so the
    stacked member axis divides evenly across devices."""
    pad = (-len(trees)) % data
    return list(trees) + list(trees[-1:]) * pad


def batch_sharding(mesh: Mesh, ndim: int = 2, batch_size: int = 0):
    """Inputs: batch over (pod, data); rest unsharded. If ``batch_size`` is
    given, mesh axes that do not divide it are dropped (e.g. batch=1
    long-context decode runs batch-replicated, sharded over model only)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch_size:
        kept, size = [], 1
        for a in axes:
            if batch_size % (size * mesh.shape[a]) == 0:
                kept.append(a)
                size *= mesh.shape[a]
        axes = tuple(kept)
    if not axes:
        return NamedSharding(mesh, P(*([None] * ndim)))
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0],
                                 *([None] * (ndim - 1))))
