"""ShapeDtypeStruct stand-ins for every model input (dry-run deliverable).

``input_specs(cfg, shape)`` returns the abstract inputs for the step the
shape cell lowers (train_step / prefill / serve_step) — weak-type-correct,
shardable, zero allocation. ``model_flops(cfg, shape)`` provides the
6·N·D-style useful-FLOPs denominator for §Roofline.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.compress import lm_layer_specs
from repro.models import layers as L
from repro.models import model as M


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                cache_bits: int = 16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    cdt = L.dtype_of(cfg.compute_dtype)
    if shape.mode in ("train", "prefill"):
        batch = {}
        if cfg.frontend == "audio_stub":
            batch["embeds"] = _sds((B, S, cfg.d_model), cdt)
            if shape.mode == "train":
                batch["labels"] = _sds((B, S), jnp.int32)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
            if cfg.frontend == "vision_stub":
                batch["embeds"] = _sds((B, cfg.frontend_len, cfg.d_model),
                                       cdt)
        return batch
    # decode: one token against a cache of length S
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, dtype=cdt, cache_bits=cache_bits))
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "cache": cache,
        "pos": _sds((), jnp.int32),
    }


def params_shape(cfg: ArchConfig, deploy_bits=None):
    """Abstract params; ``deploy_bits`` composes deployment quantization
    (core/deploy.py) — still zero allocation via eval_shape."""
    if deploy_bits is None:
        return jax.eval_shape(lambda: M.init(cfg, jax.random.PRNGKey(0)))
    from repro.core.deploy import quantize_params_for_deploy
    return jax.eval_shape(lambda: quantize_params_for_deploy(
        M.init(cfg, jax.random.PRNGKey(0)), deploy_bits))


def _fwd_flops_per_token(cfg: ArchConfig, ctx_len: int) -> float:
    total = 0.0
    for s in lm_layer_specs(cfg):
        total += s.flops_per_token
        if s.kind == "attn_qkv":
            S_eff = min(ctx_len, cfg.window) if cfg.attention == "sliding" \
                else ctx_len
            causal_frac = 0.5 if not cfg.is_encoder else 1.0
            total += 4.0 * S_eff * s.extra["head_dim"] * cfg.num_heads \
                * causal_frac
        elif s.kind == "ssm_in" and cfg.ssm:
            total += 6.0 * cfg.ssm.d_state * (cfg.ssm.expand * cfg.d_model)
    return total


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs per step: 6·N·D-style (3x forward for train)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        return 3.0 * _fwd_flops_per_token(cfg, S) * B * S
    if shape.mode == "prefill":
        return _fwd_flops_per_token(cfg, S) * B * S
    # decode: 1 token per sequence, full context attention
    per_tok = _fwd_flops_per_token_decode(cfg, S)
    return per_tok * B


def _fwd_flops_per_token_decode(cfg: ArchConfig, ctx_len: int) -> float:
    total = 0.0
    for s in lm_layer_specs(cfg):
        fpt = s.flops_per_token
        if s.kind in ("moe_up", "moe_down"):
            pass  # already top-k scaled
        total += fpt
        if s.kind == "attn_qkv":
            S_eff = min(ctx_len, cfg.window) if cfg.attention == "sliding" \
                else ctx_len
            total += 4.0 * S_eff * s.extra["head_dim"] * cfg.num_heads
        elif s.kind == "ssm_in" and cfg.ssm:
            total += 6.0 * cfg.ssm.d_state * (cfg.ssm.expand * cfg.d_model)
    return total
