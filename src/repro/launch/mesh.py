"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

Topology (TPU v5e): 16×16 chips per pod (256), ICI within a pod; the
``pod`` axis spans pods over DCN. Axes:
  data  — batch / FSDP shards (gradient + FSDP collectives)
  model — TP / EP shards (activation collectives)
  pod   — extra data parallelism across pods (gradient all-reduce on DCN,
          optionally compressed — optim/grad_compression.py)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests and fleets (requires >= data*model local devices).

    Raises a ``ValueError`` naming the required device count when the host
    has too few — ``jax.make_mesh`` otherwise fails with an opaque reshape
    error deep inside device assignment.
    """
    need = data * model
    have = len(jax.devices())
    if have < need:
        raise ValueError(
            f"make_dev_mesh(data={data}, model={model}) needs {need} local "
            f"device(s) but only {have} are visible. On CPU, launch a fresh "
            f"process with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} (must be set before jax initializes), or shrink the "
            "mesh — e.g. distributed.fault_tolerance.elastic_data_axis "
            "picks the largest data axis the surviving devices support.")
    return jax.make_mesh((data, model), ("data", "model"))
