"""Serving launcher: batched decode with a KV cache (+ optional Galen
compression policy applied at load time).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.registry import get_config
from repro.train.train_step import make_serve_step


def decode_loop(cfg, params, batch: int, steps: int, max_len: int,
                cspec=None, prompt=None):
    step = jax.jit(make_serve_step(cfg, cspec=cspec))
    cache = M.init_cache(cfg, batch, max_len)
    toks = (prompt if prompt is not None
            else jnp.zeros((batch, 1), jnp.int32))
    out = [toks]
    t0 = time.perf_counter()
    for pos in range(steps):
        logits, cache = step(params, cache, toks, pos)
        toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    return jnp.concatenate(out, 1), dt


def sustained_throughput(cfg, params, batch: int, steps: int, max_len: int,
                         cspec=None, requests: int = 4):
    """Serving throughput under SUSTAINED batched requests: one jit-warm
    decode (compile + first-touch excluded), then ``requests`` fresh
    batched decode requests back to back against the same compiled step
    and a re-initialized KV cache per request — the steady-state tok/s a
    deployed (possibly compressed) model actually sustains.

    Returns ``(tok_per_s, per_request_seconds)``."""
    step = jax.jit(make_serve_step(cfg, cspec=cspec))
    prompt0 = jnp.zeros((batch, 1), jnp.int32)

    def one_request():
        cache = M.init_cache(cfg, batch, max_len)
        toks = prompt0
        for pos in range(steps):
            logits, cache = step(params, cache, toks, pos)
            toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(toks)

    one_request()                      # warm: compile + first dispatch
    times = []
    t_all = time.perf_counter()
    for _ in range(requests):
        t0 = time.perf_counter()
        one_request()
        times.append(time.perf_counter() - t0)
    dt = time.perf_counter() - t_all
    return requests * batch * steps / dt, times


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--policy", default=None,
                    help="JSON policy file from a Galen search")
    ap.add_argument("--sustained", type=int, default=0, metavar="N",
                    help="also measure steady-state tok/s over N "
                         "back-to-back batched requests")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")
    params = M.init(cfg, jax.random.PRNGKey(0))

    cspec = None
    if args.policy:
        from repro.core.compress import CompressibleLM
        from repro.core.policy import Policy
        from repro.core.spec import LayerCMP
        with open(args.policy) as f:
            rows = json.load(f)
        cm = CompressibleLM(cfg, params)
        pol = Policy([LayerCMP(**r) for r in rows])
        cspec = cm.build_cspec(pol)

    tokens, dt = decode_loop(cfg, params, args.batch, args.steps,
                             args.max_len, cspec)
    tps = args.batch * args.steps / dt
    print(f"[serve] {args.arch}: {args.steps} steps x batch {args.batch} "
          f"in {dt:.2f}s -> {tps:.1f} tok/s (CPU)")
    print("[serve] sample:", tokens[0, :16].tolist())

    if args.sustained > 0:
        tok_s, times = sustained_throughput(
            cfg, params, args.batch, args.steps, args.max_len, cspec,
            requests=args.sustained)
        print(f"[serve] sustained: {args.sustained} requests -> "
              f"{tok_s:.1f} tok/s "
              f"(per-request {min(times):.3f}-{max(times):.3f}s)")


if __name__ == "__main__":
    main()
