"""Serving launcher: batched decode with a KV cache (+ optional Galen
compression policy applied at load time).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.registry import get_config
from repro.train.train_step import make_serve_step


def decode_loop(cfg, params, batch: int, steps: int, max_len: int,
                cspec=None, prompt=None):
    step = jax.jit(make_serve_step(cfg, cspec=cspec))
    cache = M.init_cache(cfg, batch, max_len)
    toks = (prompt if prompt is not None
            else jnp.zeros((batch, 1), jnp.int32))
    out = [toks]
    t0 = time.perf_counter()
    for pos in range(steps):
        logits, cache = step(params, cache, toks, pos)
        toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    return jnp.concatenate(out, 1), dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--policy", default=None,
                    help="JSON policy file from a Galen search")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")
    params = M.init(cfg, jax.random.PRNGKey(0))

    cspec = None
    if args.policy:
        from repro.core.compress import CompressibleLM
        from repro.core.policy import Policy
        from repro.core.spec import LayerCMP
        with open(args.policy) as f:
            rows = json.load(f)
        cm = CompressibleLM(cfg, params)
        pol = Policy([LayerCMP(**r) for r in rows])
        cspec = cm.build_cspec(pol)

    tokens, dt = decode_loop(cfg, params, args.batch, args.steps,
                             args.max_len, cspec)
    tps = args.batch * args.steps / dt
    print(f"[serve] {args.arch}: {args.steps} steps x batch {args.batch} "
          f"in {dt:.2f}s -> {tps:.1f} tok/s (CPU)")
    print("[serve] sample:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
