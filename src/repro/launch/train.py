"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --smoke --ckpt-dir /tmp/ckpt

On a real TPU cluster this runs one process per host (jax.distributed);
offline it runs the same code path on CPU with the smoke config. The
fault-tolerance loop: any StepTimeout / preemption -> reload latest atomic
checkpoint -> continue (data pipeline is a pure function of (seed, step)).
"""
from __future__ import annotations

import argparse

import jax

from repro.data.pipeline import DataConfig, Prefetcher, ShardedTokenDataset
from repro.distributed.fault_tolerance import StepTimeout
from repro.models.registry import get_config
from repro.optim.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--data", default=None,
                    help="token-shard dir or synthetic://<vocab>")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default=None,
                    help="cosine|wsd|constant (default: per-arch)")
    ap.add_argument("--max-retries", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    # per-arch schedule default: MiniCPM trains with WSD (arXiv:2404.06395)
    schedule = args.schedule or ("wsd" if "minicpm" in args.arch
                                 else "cosine")
    opt_cfg = OptimizerConfig(lr=args.lr, schedule=schedule,
                              warmup_steps=max(10, args.steps // 20),
                              total_steps=args.steps,
                              moment_dtype="bfloat16"
                              if cfg.param_dtype == "bfloat16" else "float32")
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         log_every=max(1, args.steps // 20))
    data_path = args.data or f"synthetic://{cfg.vocab_size}"
    ds = ShardedTokenDataset(
        data_path, DataConfig(seq_len=args.seq_len,
                              global_batch=args.global_batch,
                              shuffle_seed=0),
        host_id=jax.process_index(), num_hosts=jax.process_count())

    for attempt in range(args.max_retries):
        trainer = Trainer(cfg, opt_cfg, tcfg, seed=0)
        trainer.maybe_restore()
        start = trainer.step
        it = (ds.batch_at(s) for s in range(start, args.steps + 1))
        try:
            hist = trainer.fit(Prefetcher(iter(it), depth=2))
            for row in hist:
                print(row, flush=True)
            print(f"[train] done at step {trainer.step}; "
                  f"median step {trainer.monitor.median_step_s * 1e3:.1f}ms; "
                  f"stragglers {len(trainer.monitor.stragglers)}")
            return
        except StepTimeout as e:   # node hang -> restart from checkpoint
            print(f"[train] {e}; restarting from latest checkpoint "
                  f"(attempt {attempt + 1})", flush=True)
    raise SystemExit("exceeded retry budget")


if __name__ == "__main__":
    main()
