"""Fleet launcher: mesh-sharded population search with preemption-safe
epoch checkpoints (``core.search.FleetSearch``).

A fleet is P member searches — one per seed and/or hardware target —
whose stacked epoch carries are committed to a device mesh along the
member axis, so the population's single ``jit(vmap(epoch))`` dispatch
runs one member per device. Every ``--ckpt-every`` epochs the stacked
carry lands in an atomic async checkpoint; a restarted fleet restores
the newest intact step, re-shards it onto whatever mesh the surviving
devices support (``elastic_data_axis``), and resumes from the recorded
episode cursor — bit-exact when the mesh shape is unchanged.

On CPU the device count is fixed at first jax init, so multi-device
fleets need a FRESH process launched with::

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  JAX_PLATFORMS=cpu PYTHONPATH=src python -m repro.launch.fleet \\
      --members 4 --data 4 --episodes 32 --ckpt-dir /tmp/fleet

(the flag must precede every jax import — same recipe as
``launch/dryrun.py``). ``--data 0`` runs the same fleet without a mesh
(plain single-device PopulationSearch dispatch), which is the parity
arm the fleet tests and the ``fleet_scaling`` benchmark compare
against.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional, Sequence

import jax

from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import (FleetSearch, FusedCompressionSearch,
                               SearchConfig)
from repro.distributed.fault_tolerance import elastic_data_axis
from repro.launch.mesh import make_dev_mesh


def fleet_data_axis(members: int, model: int = 1) -> int:
    """Data-axis extent for a fleet of ``members`` on THIS process's
    devices: the largest power-of-two the devices support, capped at the
    member count (a data axis wider than P would only shard padding)."""
    data = elastic_data_axis(1, len(jax.devices()), model)
    while data > max(1, members):
        data //= 2
    return data


def fleet_mesh(members: int, data: Optional[int] = None, model: int = 1):
    """Mesh for a fleet: ``data=None`` sizes the data axis automatically
    via ``fleet_data_axis``; ``data=0`` means no mesh (single-device
    population dispatch)."""
    if data == 0:
        return None
    if data is None:
        data = fleet_data_axis(members, model)
    return make_dev_mesh(data=data, model=model)


def tiny_fleet(members: int = 4, data: Optional[int] = None,
               methods: str = "pq", batch_size: int = 4,
               epoch_batches: int = 2, updates: int = 2, seed0: int = 0,
               warmup_episodes: int = 4, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 1, mesh=None) -> FleetSearch:
    """P same-method members (one per seed) on the tiny untrained LM —
    the fleet the subprocess tests and the ``fleet_scaling`` benchmark
    drive. Members share the model, validation batch, and ONE
    sensitivity analysis, so the fleet constructor pays it once and the
    epochs fuse into a single (sharded) dispatch."""
    import jax.random as jr

    from repro.configs.base import ArchConfig
    from repro.core.compress import CompressibleLM
    from repro.data.pipeline import bigram_lm
    from repro.models import model as M

    cfg = ArchConfig(name="tiny-fleet", num_layers=3, d_model=64,
                     num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256,
                     vocab_size=128, scan_layers=True)
    cm = CompressibleLM(cfg, M.init(cfg, jr.PRNGKey(0)))
    batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    engines, sens = [], None
    for p in range(members):
        scfg = SearchConfig(
            methods=methods, episodes=64,
            reward=RewardConfig(target_ratio=0.5),
            ddpg=DDPGConfig(warmup_episodes=warmup_episodes,
                            updates_per_episode=updates,
                            batch_size=16, buffer_size=256),
            seed=seed0 + p)
        m = FusedCompressionSearch(cm, batch, scfg, ctx, sens=sens,
                                   batch_size=batch_size,
                                   epoch_batches=epoch_batches)
        sens = m.sens
        engines.append(m)
    if mesh is None:
        mesh = fleet_mesh(members, data)
    return FleetSearch(engines, mesh=mesh, fuse_rollouts=True,
                       ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)


def _records_json(results) -> list:
    """Per-member [(episode, reward, accuracy, latency_s, sigma), ...] —
    the comparable record surface (policies compare via these)."""
    return [[(r.episode, float(r.reward), float(r.accuracy),
              float(r.latency_s), float(r.sigma)) for r in res.history]
            for res in results]


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--data", type=int, default=None,
                    help="mesh data-axis extent; 0 = no mesh "
                         "(single-device dispatch); default: largest "
                         "power of two the devices support, capped at "
                         "--members")
    ap.add_argument("--methods", default="pq")
    ap.add_argument("--episodes", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--epoch-batches", type=int, default=2)
    ap.add_argument("--updates", type=int, default=2)
    ap.add_argument("--seed0", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=1)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --ckpt-dir "
                         "before running (resumes from its cursor)")
    ap.add_argument("--stop-after-epochs", type=int, default=0,
                    help="simulate preemption: exit after N epoch "
                         "dispatches (checkpoint cadence still applies)")
    ap.add_argument("--json", action="store_true",
                    help="print a JSON result blob on the last line")
    ap.add_argument("--verbose", action="store_true")
    a = ap.parse_args(argv)

    fleet = tiny_fleet(members=a.members, data=a.data, methods=a.methods,
                       batch_size=a.batch_size,
                       epoch_batches=a.epoch_batches, updates=a.updates,
                       seed0=a.seed0, ckpt_dir=a.ckpt_dir,
                       ckpt_every=a.ckpt_every)
    if a.resume:
        extra = fleet.restore_latest_checkpoint()
        if a.verbose and extra is not None:
            print(f"resumed at episode {fleet.epoch_cursor} "
                  f"(saved on mesh {extra['mesh_shape']})", flush=True)
    episodes = a.episodes
    if a.stop_after_epochs:
        per_epoch = a.batch_size * a.epoch_batches
        episodes = min(episodes, fleet.epoch_cursor
                       + a.stop_after_epochs * per_epoch)
    t0 = time.perf_counter()
    results = fleet.run_fleet(episodes, verbose=a.verbose)
    dt = time.perf_counter() - t0
    ran = sum(len(r.history) for r in results)
    out = {
        "devices": len(jax.devices()),
        "mesh": dict(fleet.mesh.shape) if fleet.mesh is not None else None,
        "members": a.members,
        "epoch_cursor": fleet.epoch_cursor,
        "epochs_run": fleet.epochs_run,
        "episodes_ran": ran,
        "eps_per_s": round(ran / dt, 3) if dt > 0 else 0.0,
        "monitor": fleet.monitor.summary(),
        "records": _records_json(results),
    }
    if a.json:
        print(json.dumps(out), flush=True)
    elif a.verbose:
        print(f"{ran} episodes in {dt:.2f}s "
              f"({out['eps_per_s']} eps/s aggregate)", flush=True)
    return out


if __name__ == "__main__":
    main()
