import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell for the production meshes and extract
the §Roofline terms from the compiled artifact.

MUST be a fresh process (jax locks the device count at first init) — the
XLA_FLAGS line above precedes every other import.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]

Writes one JSON per cell under artifacts/dryrun/<mesh>/<arch>__<shape>.json
(resumable: existing files are skipped unless --force).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ALL_SHAPES, SHAPES_BY_NAME, cell_supported
from repro.core.latency import V5E, hlo_collective_bytes, roofline_from_compiled
from repro.distributed.sharding import (axis_rules, batch_sharding,
                                        cache_shardings, param_shardings,
                                        replicated)
from repro.launch.inputs import input_specs, model_flops, params_shape
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.registry import ARCH_IDS, get_config
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_serve_step, make_train_step

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts",
                   "dryrun")


def _batch_shardings(batch_specs, mesh):
    return {k: batch_sharding(mesh, v.ndim, v.shape[0]) if k != "pos"
            else replicated(mesh) for k, v in batch_specs.items()}


def _lower(cfg, shape, mesh, hw=V5E, deploy_bits=None, cache_bits=16):
    """Lower + compile one step for ``cfg``. Returns (row dict, compiled).
    ``deploy_bits``/``cache_bits``: §Perf variants — integer weight storage
    and quantized KV cache on the serving path."""

    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    scanned = cfg.scan_layers and cfg.homogeneous
    t0 = time.time()
    with axis_rules(mesh):
        p_shape = params_shape(cfg, deploy_bits)
        p_shard = param_shardings(p_shape, mesh, scanned=scanned)
        batch_specs = input_specs(cfg, shape, cache_bits=cache_bits)

        if shape.mode == "train":
            opt_cfg = OptimizerConfig(
                moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16"
                else "float32")
            opt_shape = jax.eval_shape(
                lambda p: adamw_init(p, opt_cfg), p_shape)
            opt_shard = {"m": p_shard, "v": p_shard,
                         "step": replicated(mesh)}
            b_shard = _batch_shardings(batch_specs, mesh)
            step = make_train_step(cfg, opt_cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, opt_shard, b_shard),
                             out_shardings=(p_shard, opt_shard, None))
            lowered = jitted.lower(p_shape, opt_shape, batch_specs)
        elif shape.mode == "prefill":
            b_shard = _batch_shardings(batch_specs, mesh)

            def fwd(params, batch):
                return M.forward(cfg, params, tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"))
            jitted = jax.jit(fwd, in_shardings=(p_shard, b_shard),
                             out_shardings=None)
            lowered = jitted.lower(p_shape, batch_specs)
        else:  # decode
            cache_shape = batch_specs["cache"]
            c_shard = cache_shardings(cache_shape, mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard,
                              batch_sharding(
                                  mesh, 2,
                                  batch_specs["tokens"].shape[0]),
                              replicated(mesh)),
                out_shardings=(None, c_shard))
            lowered = jitted.lower(p_shape, cache_shape,
                                   batch_specs["tokens"],
                                   batch_specs["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mf = model_flops(cfg, shape)
    rep = roofline_from_compiled(compiled, chips=chips, hw=hw,
                                 model_flops=mf)
    n_params = sum(x.size for x in jax.tree.leaves(p_shape))
    row = {
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "axes": list(mesh.axis_names), "chips": chips,
        "params": int(n_params),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **{k: (v if not isinstance(v, float) else float(v))
           for k, v in rep.summary().items()},
        "per_collective": {k: v for k, v in rep.per_collective.items()
                           if not k.startswith("_")},
        "collective_counts": rep.per_collective.get("_counts", {}),
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                row[attr] = int(v)
    # analytic bytes-per-device: params+opt live on device, sharded
    bytes_per_dev = 0
    for x in jax.tree.leaves(p_shape):
        bytes_per_dev += x.size * x.dtype.itemsize
    mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[shape.mode]
    row["param_state_bytes_per_dev"] = int(bytes_per_dev * mult / chips)
    return row, compiled


def _recombine(full_row, r1, r2, L, hw, mf, chips):
    """Two-point extrapolation over unrolled probe compiles (XLA's
    cost_analysis counts a while/scan body ONCE — probes at 1 and 2
    unrolled layers give exact per-layer deltas: total = c1 + (L-1)(c2-c1))."""
    out = dict(full_row)
    for key in ("flops", "bytes", "collective_bytes"):
        c1, c2 = r1[key], r2[key]
        out[key] = c1 + (L - 1) * (c2 - c1)
    out["per_collective"] = {
        k: r1["per_collective"].get(k, 0.0)
        + (L - 1) * (r2["per_collective"].get(k, 0.0)
                     - r1["per_collective"].get(k, 0.0))
        for k in set(r1["per_collective"]) | set(r2["per_collective"])}
    from repro.core.latency import RooflineReport
    rep = RooflineReport(flops=out["flops"], bytes_accessed=out["bytes"],
                         collective_bytes=max(0.0, out["collective_bytes"]),
                         per_collective=out["per_collective"], chips=chips,
                         hw=hw, model_flops=mf)
    out.update({k: (float(v) if isinstance(v, float) else v)
                for k, v in rep.summary().items()})
    out["extrapolated"] = True
    return out


def lower_cell(arch_id: str, shape_name: str, mesh, *, hw=V5E,
               probes: bool = True):
    """One dry-run cell: full compile (proves sharding + memory) plus, for
    scan-stacked archs, two unrolled probe compiles for exact roofline
    terms (see _recombine)."""
    shape = SHAPES_BY_NAME[shape_name]
    cfg = get_config(arch_id)
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "skipped": reason}, None
    if shape.mode == "train":
        cfg = cfg.replace(remat="full")
    row, compiled = _lower(cfg, shape, mesh, hw)
    scanned = cfg.scan_layers and cfg.homogeneous
    if scanned and probes:
        chips = row["chips"]
        mf = model_flops(cfg, shape)
        probe1 = cfg.replace(num_layers=1, scan_layers=False)
        probe2 = cfg.replace(num_layers=2, scan_layers=False)
        r1, _ = _lower(probe1, shape, mesh, hw)
        r2, _ = _lower(probe2, shape, mesh, hw)
        row = _recombine(row, r1, r2, cfg.num_layers, hw, mf, chips)
    row.update({"arch": arch_id, "shape": shape_name})
    return row, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=ART)
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = list(ARCH_IDS) if args.all or args.arch is None else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.all or args.shape is None \
        else [args.shape]

    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        mdir = os.path.join(args.out, "multipod" if mp else "singlepod")
        os.makedirs(mdir, exist_ok=True)
        for arch in archs:
            for shp in shapes:
                path = os.path.join(mdir, f"{arch}__{shp}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {path}")
                    continue
                print(f"=== {arch} × {shp} on "
                      f"{'multipod' if mp else 'singlepod'} ===", flush=True)
                try:
                    row, _ = lower_cell(arch, shp, mesh)
                except Exception as e:  # a failure here is a bug — record it
                    row = {"arch": arch, "shape": shp, "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(row["traceback"], flush=True)
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
                keys = ("skipped", "error", "compile_s", "dominant",
                        "step_s", "roofline_fraction")
                print({k: row[k] for k in keys if k in row}, flush=True)


if __name__ == "__main__":
    main()
