"""Flash attention Pallas kernel — GQA, causal / bidirectional / sliding
window. TPU substrate hot spot for prefill_32k (and the reference target
the jnp chunked path in models/layers.py mirrors).

Grid: (batch*q_heads, S/bq, S/bk) with the K axis innermost sequential;
online-softmax running stats (m, l) and the output accumulator live in
VMEM scratch. KV blocks are indexed through the GQA group map
(q head h -> kv head h // group). Window/causal masking is applied
in-block with absolute positions derived from the block indices.

VMEM at defaults (bq=bk=512, D=128): q 256KB + k/v 512KB + acc 256KB
+ stats ≈ 1.1MB. MXU dims: bq×D and bk×D tiles, 128-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  seq_len: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # [bq, D]
    k = k_ref[0]                       # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = True) -> jnp.ndarray:
    """q [B, H, S, D]; k, v [B, KV, S, D] -> [B, H, S, D]."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    bq, bk = min(bq, S), min(bk, S)
    lcm = bq * bk // math.gcd(bq, bk)
    P = math.ceil(S / lcm) * lcm
    if P != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, P - S), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, P - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, P - S), (0, 0)))
    Sp = q.shape[2]
    qf = q.reshape(B * H, Sp, D)
    kf = k.reshape(B * KV, Sp, D)
    vf = v.reshape(B * KV, Sp, D)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        # flattened q index h = b*H + hh  ->  kv index b*KV + hh // G
        return ((h // H) * KV + (h % H) // G, j, 0)

    scale = 1.0 / math.sqrt(D)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_len=S),
        grid=(B * H, Sp // bq, Sp // bk),
        in_specs=[pl.BlockSpec((1, bq, D), q_map),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map)],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sp, D)[:, :, :S]
