"""Quantized matmul Pallas kernels — the TPU replacement for the paper's
ARM bit-serial operators (DESIGN.md §1).

Two entry kernels:

* ``int8_matmul_kernel``   — int8 x int8 -> int32 on the MXU with fused
  asymmetric dequantization (per-row activation scale/zero, per-column
  weight scale/zero). Convention: zero offsets are ADDED back on
  dequantization, x = sx·(xq + zx) and w = sw·(wq + zw), so
      y[m,n] = sx[m]·sw[n]·(acc[m,n] + zx[m]·Σ_k wq[k,n]
                            + zw[n]·Σ_k xq[m,k] + K·zx[m]·zw[n])
  (matches ``_dequant_epilogue`` and ``ref.int8_matmul_ref``; locked by
  the asymmetric zero-point test in tests/test_kernels.py).
* ``int4_matmul_kernel``   — weights stored packed two-per-byte (the MIX
  ≤4-bit policy path); unpacked in-VMEM, then the same int8 MXU pipeline.
  The win is HBM/ICI traffic (half of int8), not FLOPs — exactly the
  hardware truth the latency oracle teaches the agent.

Tiling: (bm × bk) x (bk × bn) blocks, K innermost ("arbitrary") grid dim
accumulating into an int32 VMEM scratch; dequant epilogue on the last K
step. All dims must be multiples of the block shape — ``ops.py`` pads.
VMEM at defaults (bm=bk=bn=256): x 64KB + w 64KB + acc 256KB + out 128KB
≈ 0.5MB, comfortably inside the ~16MB/core budget; MXU dims 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BM = 256
DEFAULT_BK = 256
DEFAULT_BN = 256


def _dequant_epilogue(acc, xsum_blk, wsum_blk, sx, zx, sw, zw, k_total):
    """acc int32 [bm,bn]; sums int32; scales f32. Returns f32 [bm,bn].
    Convention (paper Eq. 3): x = sx·(xq + zx), w = sw·(wq + zw), so
    Σ x·w = sx·sw·(acc + zx·Σwq + zw·Σxq + K·zx·zw)."""
    accf = acc.astype(jnp.float32)
    corr = (accf
            + zx[:, None] * wsum_blk[None, :].astype(jnp.float32)
            + zw[None, :] * xsum_blk[:, None].astype(jnp.float32)
            + k_total * zx[:, None] * zw[None, :])
    return sx[:, None] * sw[None, :] * corr


def int8_matmul_kernel(xq_ref, wq_ref, sx_ref, zx_ref, sw_ref, zw_ref,
                       o_ref, acc_ref, xsum_ref, wsum_ref, *, k_total: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)
        wsum_ref[...] = jnp.zeros_like(wsum_ref)

    xq = xq_ref[...]
    wq = wq_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    xsum_ref[...] += jnp.sum(xq.astype(jnp.int32), axis=1)
    wsum_ref[...] += jnp.sum(wq.astype(jnp.int32), axis=0)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        y = _dequant_epilogue(acc_ref[...], xsum_ref[...], wsum_ref[...],
                              sx_ref[...], zx_ref[...],
                              sw_ref[...], zw_ref[...], k_total)
        o_ref[...] = y.astype(o_ref.dtype)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """[K//2, N] int8 (two nibbles per byte along K) -> [K, N] int8 in
    [-8, 7]. Layout: byte b holds rows 2b (low nibble) and 2b+1 (high)."""
    low = jnp.left_shift(packed, 4)
    low = jnp.right_shift(low, 4)                    # sign-extend low nibble
    high = jnp.right_shift(packed, 4)                # arithmetic shift
    kk, n = packed.shape
    out = jnp.stack([low, high], axis=1).reshape(2 * kk, n)
    return out.astype(jnp.int8)


def int4_matmul_kernel(xq_ref, wp_ref, sx_ref, zx_ref, sw_ref, zw_ref,
                       o_ref, acc_ref, xsum_ref, wsum_ref, *, k_total: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)
        wsum_ref[...] = jnp.zeros_like(wsum_ref)

    xq = xq_ref[...]
    wq = unpack_int4(wp_ref[...])                    # in-VMEM unpack
    acc_ref[...] += jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    xsum_ref[...] += jnp.sum(xq.astype(jnp.int32), axis=1)
    wsum_ref[...] += jnp.sum(wq.astype(jnp.int32), axis=0)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        y = _dequant_epilogue(acc_ref[...], xsum_ref[...], wsum_ref[...],
                              sx_ref[...], zx_ref[...],
                              sw_ref[...], zw_ref[...], k_total)
        o_ref[...] = y.astype(o_ref.dtype)


def _specs(bm, bk, bn, packed_w: bool):
    kw = 2 if packed_w else 1
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),          # xq
        pl.BlockSpec((bk // kw, bn), lambda i, j, k: (k, j)),    # wq / packed
        pl.BlockSpec((bm,), lambda i, j, k: (i,)),               # sx
        pl.BlockSpec((bm,), lambda i, j, k: (i,)),               # zx
        pl.BlockSpec((bn,), lambda i, j, k: (j,)),               # sw
        pl.BlockSpec((bn,), lambda i, j, k: (j,)),               # zw
    ]
    out_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    return in_specs, out_spec


def quant_matmul(xq, wq, sx, zx, sw, zw, *, packed: bool = False,
                 bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                 bn: int = DEFAULT_BN, out_dtype=jnp.float32,
                 k_true: int = 0, interpret: bool = True):
    """xq [M,K] int8; wq [K,N] int8 or [K//2,N] packed int4; scales f32.

    ``k_true``: the UNPADDED contraction length — the K·zx·zw zero-point
    correction must not count zero-padded rows (their xq=wq=0 entries add
    nothing to acc or the sums, but a padded K would overcount this term).
    """
    M, K = xq.shape
    N = wq.shape[1]
    if packed:
        assert wq.shape[0] * 2 == K, (wq.shape, K)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    kern = int4_matmul_kernel if packed else int8_matmul_kernel
    in_specs, out_spec = _specs(bm, bk, bn, packed)
    return pl.pallas_call(
        functools.partial(kern, k_total=k_true or K),
        grid=(M // bm, N // bn, K // bk),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm,), jnp.int32),
                        pltpu.VMEM((bn,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq, sx, zx, sw, zw)
