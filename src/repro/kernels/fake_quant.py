"""Fused fake-quantization Pallas kernel (paper Eq. 3).

One pass over the tensor: per-channel min/max reduction, scale/offset
derivation, quantize-clip-dequantize — fused so the tensor is read once
from HBM instead of three times (minmax / quant / dequant). Used by the
sensitivity analysis and QAT retraining loops where fake-quant dominates.

Layout: x viewed as [R, C] with the channel axis LAST and the dynamic-range
reduction over axis 0 (rows) — matching ``core.quantization.fake_quant``.
Blocks tile the channel axis, (R, bc) per block, so each block owns every
row of its channels and the reduction never crosses blocks.
VMEM: R ≤ 16384 rows × bc=512 × 4B ≈ 32MB worst case — ops.py shrinks bc
until the block fits a 4MB budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fake_quant_kernel(x_ref, bits_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    bits = bits_ref[0].astype(jnp.float32)
    b = jnp.clip(bits, 1.0, 31.0)
    n = 2.0 ** b - 1.0
    x_min = jnp.min(x, axis=0, keepdims=True)
    x_max = jnp.max(x, axis=0, keepdims=True)
    span = jnp.maximum(x_max - x_min, 1e-8)
    s = n / span
    z = jnp.floor(s * x_min) + 2.0 ** (b - 1.0)
    q = jnp.clip(jnp.floor(s * x - z), -n, n)
    deq = (q + z + 0.5) / s
    out = jnp.where(bits >= 32.0, x, deq)
    o_ref[...] = out.astype(o_ref.dtype)


def fake_quant_2d(x: jnp.ndarray, bits, *, bc: int = 512,
                  interpret: bool = True) -> jnp.ndarray:
    """x [R, C]: quantize-dequantize with per-channel (last axis) dynamic
    range reduced over axis 0. ``bits`` may be a traced int scalar."""
    R, C = x.shape
    bc = min(bc, C)
    while C % bc != 0:           # fall back to a divisor of C
        bc -= 1
    bits_arr = jnp.reshape(jnp.asarray(bits, jnp.int32), (1,))
    return pl.pallas_call(
        fake_quant_kernel,
        grid=(C // bc,),
        in_specs=[pl.BlockSpec((R, bc), lambda j: (0, j)),
                  pl.BlockSpec((1,), lambda j: (0,))],
        out_specs=pl.BlockSpec((R, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, bits_arr)
