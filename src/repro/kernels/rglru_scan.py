"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma substrate).

h_t = a_t ⊙ h_{t-1} + b_t — a diagonal linear recurrence. The jnp
reference uses ``associative_scan`` (log-depth, but materializes O(S)
intermediates and round-trips HBM per level); this kernel streams time
blocks through VMEM sequentially, carrying the state vector in scratch —
one HBM read of (a, b) and one write of h total.

Grid: (B, C/bc, S/bs) with time innermost sequential; channel blocks are
independent (diagonal recurrence). VMEM: 3 × bs×bc × 4B ≈ 1.5MB at
bs=128, bc=1024, + state bc.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, state_ref, *, bs: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = h0_ref[0]

    a = a_ref[0].astype(jnp.float32)            # [bs, bc]
    b = b_ref[0].astype(jnp.float32)
    h = state_ref[...]                          # [bc]

    # sequential within the block (bs small; unrolled by the compiler)
    def step(i, carry):
        h, out = carry
        h = a[i] * h + b[i]
        out = out.at[i].set(h)
        return h, out

    out0 = jnp.zeros_like(a)
    h, out = jax.lax.fori_loop(0, bs, step, (h, out0))
    state_ref[...] = h
    o_ref[0] = out.astype(o_ref.dtype)


def rglru_scan(a, b, h0=None, *, bs: int = 128, bc: int = 1024,
               interpret: bool = True):
    """a, b: [B, S, C]; h0: [B, C] initial state. Returns h: [B, S, C]."""
    B, S, C = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    bs, bc = min(bs, S), min(bc, C)
    assert S % bs == 0 and C % bc == 0, (S, C, bs, bc)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=(B, C // bc, S // bs),
        in_specs=[pl.BlockSpec((1, bs, bc), lambda bi, ci, ti: (bi, ti, ci)),
                  pl.BlockSpec((1, bs, bc), lambda bi, ci, ti: (bi, ti, ci)),
                  pl.BlockSpec((1, bc), lambda bi, ci, ti: (bi, ci))],
        out_specs=pl.BlockSpec((1, bs, bc), lambda bi, ci, ti: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), a.dtype),
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
