"""Fused 3-layer MLP forward + flat Polyak Pallas kernels — the DDPG
update path's compute (ISSUE 7).

``mlp3`` runs the whole actor/critic trunk
``x @ W1 + b1 -> relu -> @ W2 + b2 -> relu -> @ W3 + b3 [-> sigmoid]``
as ONE kernel: the weights live in VMEM for the whole grid and the
intermediate activations never round-trip through HBM — on TPU the three
GEMMs feed the MXU back to back instead of dispatching three tiny
(B, 400)x(400, 300)-class matmuls with HBM writes between them. The
hidden activations h1/h2 are emitted as extra outputs so a reference
``custom_vjp`` backward (kernels.ops.fused_mlp3) can reuse them.

``polyak`` is the soft-target update ``t = (1 - tau) * t + tau * p`` over
a FLATTENED parameter buffer: one elementwise kernel pass over the whole
network instead of one dispatch per parameter leaf.

Shapes must be kernel-legal before the call: callers (kernels.ops) pad
the batch axis to the f32 sublane multiple (8) and every feature axis to
the lane multiple (128). Zero padding is correctness-preserving here:
padded x columns meet padded (zero) W rows, padded b entries are zero,
and ``relu(0) = 0`` keeps padded hidden columns zero through the stack —
only the final sigmoid makes padded output columns nonzero (0.5), which
the wrapper slices away.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp3_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                 y_ref, h1_ref, h2_ref, *, sigmoid: bool):
    x = x_ref[...].astype(jnp.float32)
    h1 = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...], 0.0)
    h2 = jnp.maximum(
        jnp.dot(h1, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...], 0.0)
    y = jnp.dot(h2, w3_ref[...], preferred_element_type=jnp.float32) \
        + b3_ref[...]
    if sigmoid:
        y = jax.nn.sigmoid(y)
    y_ref[...] = y.astype(y_ref.dtype)
    h1_ref[...] = h1.astype(h1_ref.dtype)
    h2_ref[...] = h2.astype(h2_ref.dtype)


def mlp3(x, w1, b1, w2, b2, w3, b3, *, sigmoid: bool = False,
         bm: int = 128, interpret: bool = True):
    """Fused 3-layer MLP forward on pre-padded operands.

    x [B, D0]; wi [D(i-1), Di]; bi [1, Di] (2D so the lane layout is
    explicit). Returns ``(y [B, D3], h1 [B, D1], h2 [B, D2])`` — the
    hidden activations are the residuals the reference backward needs.
    The grid tiles the batch axis only; every weight block is the whole
    (padded) matrix, resident in VMEM across the grid.
    """
    B, D0 = x.shape
    D1, D2, D3 = w1.shape[1], w2.shape[1], w3.shape[1]
    bm = min(bm, B)
    while B % bm != 0:          # fall back to a divisor of B
        bm -= 1
    import functools
    kern = functools.partial(_mlp3_kernel, sigmoid=sigmoid)
    full = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))
    return pl.pallas_call(
        kern,
        grid=(B // bm,),
        in_specs=[pl.BlockSpec((bm, D0), lambda i: (i, 0)),
                  full(D0, D1), full(1, D1),
                  full(D1, D2), full(1, D2),
                  full(D2, D3), full(1, D3)],
        out_specs=[pl.BlockSpec((bm, D3), lambda i: (i, 0)),
                   pl.BlockSpec((bm, D1), lambda i: (i, 0)),
                   pl.BlockSpec((bm, D2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, D3), x.dtype),
                   jax.ShapeDtypeStruct((B, D1), x.dtype),
                   jax.ShapeDtypeStruct((B, D2), x.dtype)],
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3)


def _polyak_kernel(t_ref, p_ref, tau_ref, o_ref):
    tau = tau_ref[0]
    o_ref[...] = (1.0 - tau) * t_ref[...] + tau * p_ref[...]


def polyak_flat(target, online, tau, *, br: int = 256,
                interpret: bool = True):
    """``(1 - tau) * target + tau * online`` over [R, 128] flat views —
    the whole network's soft-target update as one kernel pass."""
    R, C = target.shape
    br = min(br, R)
    while R % br != 0:
        br -= 1
    tau_arr = jnp.reshape(jnp.asarray(tau, target.dtype), (1,))
    return pl.pallas_call(
        _polyak_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), target.dtype),
        interpret=interpret,
    )(target, online, tau_arr)
