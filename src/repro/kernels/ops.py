"""Jit'd public wrappers around the Pallas kernels.

Each op pads/reshapes to kernel-legal shapes, dispatches to the kernel
(``interpret=True`` on CPU — the dev/test path; on TPU backends the same
call compiles to Mosaic), and restores the caller's layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fake_quant as _fq
from repro.kernels import flash_attention as _fa
from repro.kernels import mlp_fused as _mlp
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("w_bits", "out_dtype"))
def quantized_matmul(x: jnp.ndarray, w: jnp.ndarray, w_bits: int = 8,
                     out_dtype=jnp.float32) -> jnp.ndarray:
    """f32/bf16 x [M,K] @ w [K,N] through the int8/int4 quantized kernel:
    quantize per-row (x) / per-col (w), integer matmul, fused dequant."""
    M, K = x.shape
    N = w.shape[1]
    xq, sx, zx = _ref.quantize_rows(x, 8)
    bits = 4 if w_bits <= 4 else 8
    wq, sw, zw = _ref.quantize_cols(w, bits)
    interpret = not _on_tpu()
    bm = bk = bn = 256
    xq = _pad_to(_pad_to(xq, bm, 0), bk, 1)
    wq_f = _pad_to(_pad_to(wq, bk, 0), bn, 1)
    sx_p = _pad_to(sx, bm, 0)
    zx_p = _pad_to(zx, bm, 0)
    sw_p = _pad_to(sw, bn, 0)
    zw_p = _pad_to(zw, bn, 0)
    if bits == 4:
        wq_f = _ref.pack_int4(wq_f)
    y = _qm.quant_matmul(xq, wq_f, sx_p, zx_p, sw_p, zw_p,
                         packed=(bits == 4), bm=bm, bk=bk, bn=bn,
                         out_dtype=out_dtype, k_true=K, interpret=interpret)
    return y[:M, :N]


@functools.partial(jax.jit, static_argnames=())
def fused_fake_quant(x: jnp.ndarray, bits) -> jnp.ndarray:
    """Per-channel (last axis) fake quant of an arbitrary-rank tensor."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _fq.fake_quant_2d(x2, bits, interpret=not _on_tpu())
    return out.reshape(shape)


# --------------------------------------------------------------------------
# Fused DDPG update-path kernels (ISSUE 7): 3-layer MLP forward + flat
# Polyak. The forward runs in the Pallas kernel; gradients come from a
# ``custom_vjp`` whose backward is the reference jnp chain (pallas_call has
# no differentiation rule), so ``jax.grad`` through ``actor_forward`` /
# ``critic_forward`` works unchanged when the kernel path is routed.
# --------------------------------------------------------------------------

_LANE = 128      # f32 lane multiple (last axis)
_SUBLANE = 8     # f32 sublane multiple (second-to-last axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mlp3_ste(sigmoid: bool, x, w1, b1, w2, b2, w3, b3):
    y, _, _ = _mlp3_fwd_impl(sigmoid, x, w1, b1, w2, b2, w3, b3)
    return y


def _mlp3_fwd_impl(sigmoid, x, w1, b1, w2, b2, w3, b3):
    """Pad to kernel-legal tiles, run the fused kernel, slice back.

    Zero padding is exact here: padded x columns hit zero W rows, padded
    b entries are zero, and relu(0)=0 keeps padded hidden columns zero —
    see kernels.mlp_fused."""
    B, D0 = x.shape
    D1, D2, D3 = w1.shape[1], w2.shape[1], w3.shape[1]
    xp = _pad_to(_pad_to(x, _SUBLANE, 0), _LANE, 1)
    w1p = _pad_to(_pad_to(w1, _LANE, 0), _LANE, 1)
    w2p = _pad_to(_pad_to(w2, _LANE, 0), _LANE, 1)
    w3p = _pad_to(_pad_to(w3, _LANE, 0), _LANE, 1)
    b1p = _pad_to(b1.reshape(1, -1), _LANE, 1)
    b2p = _pad_to(b2.reshape(1, -1), _LANE, 1)
    b3p = _pad_to(b3.reshape(1, -1), _LANE, 1)
    y, h1, h2 = _mlp.mlp3(xp, w1p, b1p, w2p, b2p, w3p, b3p,
                          sigmoid=sigmoid, interpret=not _on_tpu())
    return y[:B, :D3], h1[:B, :D1], h2[:B, :D2]


def _mlp3_vjp_fwd(sigmoid, x, w1, b1, w2, b2, w3, b3):
    y, h1, h2 = _mlp3_fwd_impl(sigmoid, x, w1, b1, w2, b2, w3, b3)
    return y, (x, w1, w2, w3, h1, h2, y)


def _mlp3_vjp_bwd(sigmoid, res, dy):
    # reference jnp backward (relu' = z > 0 == h > 0; sigmoid' = y(1-y))
    x, w1, w2, w3, h1, h2, y = res
    dz3 = dy * y * (1.0 - y) if sigmoid else dy
    dw3 = h2.T @ dz3
    db3 = jnp.sum(dz3, axis=0)
    dz2 = (dz3 @ w3.T) * (h2 > 0)
    dw2 = h1.T @ dz2
    db2 = jnp.sum(dz2, axis=0)
    dz1 = (dz2 @ w2.T) * (h1 > 0)
    dw1 = x.T @ dz1
    db1 = jnp.sum(dz1, axis=0)
    dx = dz1 @ w1.T
    return dx, dw1, db1, dw2, db2, dw3, db3


_mlp3_ste.defvjp(_mlp3_vjp_fwd, _mlp3_vjp_bwd)


def fused_mlp3(params, x, final: str = "linear") -> jnp.ndarray:
    """Fused 3-layer MLP forward (one kernel, differentiable via the
    reference backward). ``params`` is the ddpg ``_mlp`` layout — a list
    of three ``{"w", "b"}`` layers; ``final`` is "linear" or "sigmoid"."""
    (l1, l2, l3) = params
    return _mlp3_ste(final == "sigmoid", x, l1["w"], l1["b"],
                     l2["w"], l2["b"], l3["w"], l3["b"])


def fused_polyak(target, online, tau):
    """Soft-target update ``(1 - tau) * target + tau * online`` for an
    arbitrary pytree: both trees are flattened into ONE [R, 128] buffer,
    updated in a single kernel pass, and unflattened — instead of one
    dispatch per parameter leaf."""
    t_leaves, treedef = jax.tree.flatten(target)
    p_leaves = treedef.flatten_up_to(online)
    sizes = [l.size for l in t_leaves]
    flat_t = jnp.concatenate([l.reshape(-1) for l in t_leaves])
    flat_p = jnp.concatenate([l.reshape(-1) for l in p_leaves])
    n = flat_t.shape[0]
    pad = (-n) % _LANE
    if pad:
        flat_t = jnp.pad(flat_t, (0, pad))
        flat_p = jnp.pad(flat_p, (0, pad))
    out = _mlp.polyak_flat(flat_t.reshape(-1, _LANE),
                           flat_p.reshape(-1, _LANE), tau,
                           interpret=not _on_tpu()).reshape(-1)[:n]
    offs, news = 0, []
    for leaf, size in zip(t_leaves, sizes):
        news.append(out[offs:offs + size].reshape(leaf.shape))
        offs += size
    return jax.tree.unflatten(treedef, news)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True,
                    window: int = 0) -> jnp.ndarray:
    """q [B,H,S,D]; k,v [B,KV,S,D]."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=not _on_tpu())


@jax.jit
def rglru_scan(a, b, h0=None):
    B, S, C = a.shape
    bs = 128
    while S % bs != 0:
        bs //= 2
    bc = 1024
    while C % bc != 0:
        bc //= 2
    return _rg.rglru_scan(a, b, h0, bs=max(bs, 1), bc=max(bc, 1),
                          interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xh, dA, Bm, Cm, chunk: int = 256):
    S = xh.shape[1]
    c = min(chunk, S)
    while S % c != 0:
        c //= 2
    return _ssd.ssd_scan(xh, dA, Bm, Cm, chunk=max(c, 1),
                         interpret=not _on_tpu())
