"""Jit'd public wrappers around the Pallas kernels.

Each op pads/reshapes to kernel-legal shapes, dispatches to the kernel
(``interpret=True`` on CPU — the dev/test path; on TPU backends the same
call compiles to Mosaic), and restores the caller's layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fake_quant as _fq
from repro.kernels import flash_attention as _fa
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("w_bits", "out_dtype"))
def quantized_matmul(x: jnp.ndarray, w: jnp.ndarray, w_bits: int = 8,
                     out_dtype=jnp.float32) -> jnp.ndarray:
    """f32/bf16 x [M,K] @ w [K,N] through the int8/int4 quantized kernel:
    quantize per-row (x) / per-col (w), integer matmul, fused dequant."""
    M, K = x.shape
    N = w.shape[1]
    xq, sx, zx = _ref.quantize_rows(x, 8)
    bits = 4 if w_bits <= 4 else 8
    wq, sw, zw = _ref.quantize_cols(w, bits)
    interpret = not _on_tpu()
    bm = bk = bn = 256
    xq = _pad_to(_pad_to(xq, bm, 0), bk, 1)
    wq_f = _pad_to(_pad_to(wq, bk, 0), bn, 1)
    sx_p = _pad_to(sx, bm, 0)
    zx_p = _pad_to(zx, bm, 0)
    sw_p = _pad_to(sw, bn, 0)
    zw_p = _pad_to(zw, bn, 0)
    if bits == 4:
        wq_f = _ref.pack_int4(wq_f)
    y = _qm.quant_matmul(xq, wq_f, sx_p, zx_p, sw_p, zw_p,
                         packed=(bits == 4), bm=bm, bk=bk, bn=bn,
                         out_dtype=out_dtype, k_true=K, interpret=interpret)
    return y[:M, :N]


@functools.partial(jax.jit, static_argnames=())
def fused_fake_quant(x: jnp.ndarray, bits) -> jnp.ndarray:
    """Per-channel (last axis) fake quant of an arbitrary-rank tensor."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _fq.fake_quant_2d(x2, bits, interpret=not _on_tpu())
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True,
                    window: int = 0) -> jnp.ndarray:
    """q [B,H,S,D]; k,v [B,KV,S,D]."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=not _on_tpu())


@jax.jit
def rglru_scan(a, b, h0=None):
    B, S, C = a.shape
    bs = 128
    while S % bs != 0:
        bs //= 2
    bc = 1024
    while C % bc != 0:
        bc //= 2
    return _rg.rglru_scan(a, b, h0, bs=max(bs, 1), bc=max(bc, 1),
                          interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xh, dA, Bm, Cm, chunk: int = 256):
    S = xh.shape[1]
    c = min(chunk, S)
    while S % c != 0:
        c //= 2
    return _ssd.ssd_scan(xh, dA, Bm, Cm, chunk=max(c, 1),
                         interpret=not _on_tpu())
