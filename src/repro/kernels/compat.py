"""jax version compatibility for the Pallas TPU kernels.

``pltpu.TPUCompilerParams`` (jax <= 0.4.x) was renamed to
``pltpu.CompilerParams`` in newer releases; the kwargs we use
(``dimension_semantics``) are identical in both. Resolve whichever the
installed jax provides so the kernels import everywhere.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
