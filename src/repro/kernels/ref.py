"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` deliverable).

Each function is the mathematical ground truth the kernels are tested
against (tests/test_kernels_*.py sweep shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# --- quant_matmul -----------------------------------------------------------

def quantize_rows(x: jnp.ndarray, bits: int = 8):
    """Asymmetric per-row quantization -> (q int8, scale [R], zero [R]).
    Convention matches the kernel epilogue: the zero offset is ADDED back
    on dequantization, x ≈ s·(q + z), with q in the signed range (q is
    computed as round(x/s) − z, so the z's cancel on the round trip)."""
    n = 2.0 ** bits - 1.0
    x = x.astype(jnp.float32)
    x_min = jnp.min(x, axis=1)
    x_max = jnp.max(x, axis=1)
    span = jnp.maximum(x_max - x_min, 1e-8)
    s = span / n                          # dequant scale
    z = jnp.round(x_min / s) + 2.0 ** (bits - 1)   # zero offset
    q = jnp.clip(jnp.round(x / s[:, None]) - z[:, None],
                 -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1)
    return q.astype(jnp.int8), s, z


def quantize_cols(w: jnp.ndarray, bits: int = 8):
    qT, s, z = quantize_rows(w.T, bits)
    return qT.T, s, z


def dequant_matmul_ref(xq, wq, sx, zx, sw, zw) -> jnp.ndarray:
    """Ground truth for the kernel epilogue: dequantize then matmul in f32.
    x = sx*(xq + zx), w = sw*(wq + zw)  (zero offsets are ADDED back)."""
    x = sx[:, None] * (xq.astype(jnp.float32) + zx[:, None])
    w = sw[None, :] * (wq.astype(jnp.float32) + zw[None, :])
    return x @ w


def int8_matmul_ref(xq, wq, sx, zx, sw, zw) -> jnp.ndarray:
    """Integer-accumulation form (identical math, matches kernel exactly):
    y = sx·sw·(acc + zx·colsum_w + zw·rowsum_x + K·zx·zw)."""
    acc = xq.astype(jnp.int32) @ wq.astype(jnp.int32)
    acc = acc.astype(jnp.float32)
    rowsum = jnp.sum(xq.astype(jnp.float32), axis=1)
    colsum = jnp.sum(wq.astype(jnp.float32), axis=0)
    K = xq.shape[1]
    corr = (acc + zx[:, None] * colsum[None, :]
            + zw[None, :] * rowsum[:, None]
            + K * zx[:, None] * zw[None, :])
    return sx[:, None] * sw[None, :] * corr


def pack_int4(w4: jnp.ndarray) -> jnp.ndarray:
    """[K, N] int8 in [-8,7] -> [K//2, N] packed (low nibble = even row)."""
    lo = w4[0::2].astype(jnp.uint8) & 0xF
    hi = (w4[1::2].astype(jnp.uint8) & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4_ref(packed: jnp.ndarray) -> jnp.ndarray:
    low = jnp.left_shift(packed, 4)
    low = jnp.right_shift(low, 4)
    high = jnp.right_shift(packed, 4)
    kk, n = packed.shape
    return jnp.stack([low, high], 1).reshape(2 * kk, n).astype(jnp.int8)


# --- fake_quant -------------------------------------------------------------

def fake_quant_ref(x: jnp.ndarray, bits) -> jnp.ndarray:
    """Mirror of core.quantization.fake_quant for 2-D [R, C] inputs with
    per-channel (last axis) range over axis 0."""
    from repro.core.quantization import fake_quant
    return fake_quant(x, bits, axis=(0,))


# --- flash attention --------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=0) -> jnp.ndarray:
    """q [B,H,S,D]; k,v [B,KV,S,D] -> [B,H,S,D], dense softmax."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    qq = q.reshape(B, KV, G, S, D)
    s = jnp.einsum("bkgqd,bkld->bkgql", qq.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgql,bkld->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


# --- rglru scan --------------------------------------------------------------

def rglru_scan_ref(a, b, h0=None) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t, sequential ground truth. [B,S,C]."""
    B, S, C = a.shape
    h = jnp.zeros((B, C), jnp.float32) if h0 is None else h0
    out = []
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    for t in range(S):
        h = af[:, t] * h + bf[:, t]
        out.append(h)
    return jnp.stack(out, axis=1).astype(a.dtype)


# --- ssd scan ----------------------------------------------------------------

def ssd_scan_ref(xh, dA, Bm, Cm):
    """Sequential SSD ground truth. xh [B,S,H,P]; dA [B,S,H];
    Bm, Cm [B,S,N]. Returns (y, final_state [B,H,P,N])."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        dec = jnp.exp(dA[:, t].astype(jnp.float32))            # [B,H]
        upd = jnp.einsum("bn,bhp->bhpn", Bm[:, t].astype(jnp.float32),
                         xh[:, t].astype(jnp.float32))
        state = dec[..., None, None] * state + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, t].astype(jnp.float32), state)
        ys.append(y)
    return jnp.stack(ys, 1).astype(xh.dtype), state
