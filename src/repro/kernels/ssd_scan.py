"""Mamba-2 SSD chunked-scan Pallas kernel (arXiv:2405.21060, listing 1).

Per (batch, head) the kernel walks chunks sequentially, carrying the
[P, N] state in VMEM scratch. Within a chunk (length L):

    A_cs   = cumsum(dA)                       [L]
    Lmat   = exp(segsum(dA))  (lower-tri)     [L, L]
    Y_diag = (C Bᵀ ⊙ Lmat) X                  intra-chunk, MXU
    Y_off  = diag(exp(A_cs)) C · state        inter-chunk contribution
    state  = exp(A_cs[-1]) · state + Bᵀ diag(exp(A_cs[-1]-A_cs)) X

Grid: (B, H, S/L) with the chunk axis innermost sequential. VMEM at
L=256, N=128, P=64: X 64KB + B/C 2×128KB + Lmat 256KB + state 32KB ≈ 0.6MB.
B and C are shared across heads (ngroups=1) — the index map broadcasts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, o_ref, fin_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    X = x_ref[0, :, 0].astype(jnp.float32)          # [L, P]
    dA = da_ref[0, :, 0].astype(jnp.float32)        # [L]
    Bm = b_ref[0].astype(jnp.float32)               # [L, N]
    Cm = c_ref[0].astype(jnp.float32)               # [L, N]

    A_cs = jnp.cumsum(dA)                           # [L]
    # segsum(dA)[i,j] = sum_{k=j+1..i} dA_k = A_cs[i] - A_cs[j]
    seg = A_cs[:, None] - A_cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(li >= lj, jnp.exp(seg), 0.0)   # includes diag = 1

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    Y_diag = jax.lax.dot_general(scores * Lmat, X, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_ref[...]                          # [P, N]
    decay_out = jnp.exp(A_cs)[:, None]              # [L, 1]
    Y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    Y_off = Y_off * decay_out                       # [L, P]

    total = jnp.exp(A_cs[-1])
    decay_st = jnp.exp(A_cs[-1] - A_cs)[:, None]    # [L, 1]
    upd = jax.lax.dot_general(X, Bm * decay_st, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_ref[...] = total * state + upd            # [P, N]

    o_ref[0, :, 0] = (Y_diag + Y_off).astype(o_ref.dtype)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _final():
        fin_ref[0, 0] = state_ref[...].astype(fin_ref.dtype)


def ssd_scan(xh, dA, Bm, Cm, *, chunk: int = 256, interpret: bool = True):
    """xh [B,S,H,P] (dt-scaled inputs); dA [B,S,H] log decays;
    Bm, Cm [B,S,N]. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    y, fin = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dA, Bm, Cm)
    return y, fin
