"""Optimizers and LR schedules (self-contained, sharding-friendly).

AdamW with per-leaf state that inherits the parameter sharding (ZeRO-style:
optimizer state is sharded exactly like the FSDP-sharded parameter it
belongs to). Schedules: cosine, and WSD (warmup-stable-decay, MiniCPM
arXiv:2404.06395) — the assigned minicpm-2b config's native schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"           # cosine|wsd|constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1            # WSD: fraction of steps in decay
    moment_dtype: str = "float32"      # bfloat16 for >=100B archs (DESIGN §4)


def cosine_schedule(cfg: OptimizerConfig) -> Callable:
    def f(step):
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return f


def wsd_schedule(cfg: OptimizerConfig) -> Callable:
    """Warmup-Stable-Decay: linear warmup, flat plateau, sharp decay tail."""
    decay_start = int(cfg.total_steps * (1.0 - cfg.decay_frac))

    def f(step):
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        in_decay = step > decay_start
        t = jnp.clip((step - decay_start)
                     / max(1, cfg.total_steps - decay_start), 0.0, 1.0)
        decay = jnp.where(in_decay, 1.0 - t * (1.0 - 0.1), 1.0)
        return cfg.lr * warm * decay
    return f


def get_schedule(cfg: OptimizerConfig) -> Callable:
    return {"cosine": cosine_schedule, "wsd": wsd_schedule,
            "constant": lambda c: (lambda s: c.lr)}[cfg.schedule](cfg)


def adamw_init(params, cfg: OptimizerConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def zeros(p):
        return jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptimizerConfig,
                 schedule: Optional[Callable] = None):
    """Returns (new_params, new_state, metrics)."""
    sched = schedule or get_schedule(cfg)
    step = state["step"] + 1
    lr = sched(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    b1, b2 = cfg.betas
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g
        v32 = b2 * v32 + (1 - b2) * g * g
        upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * upd
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (new_p, {"m": new_m, "v": new_v, "step": step},
            {"grad_norm": gnorm, "lr": lr})
