"""Gradient compression for cross-pod data parallelism (DESIGN §4).

Cross-pod reductions ride the slower DCN, so we provide two compressors
with error feedback (residual accumulation keeps convergence; Karimireddy
et al. 2019 "Error Feedback Fixes SignSGD"):

* int8 uniform quantization (per-leaf scale) — 4x traffic cut vs f32.
* top-k sparsification (magnitude) — k-fraction of entries + indices.

Both are pure-functional: state (the error residual) is a pytree carried by
the train step; compression happens BEFORE the pod-axis psum and
decompression after, so the in-pod ICI reduction stays full precision.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GradCompressionConfig:
    kind: str = "none"                 # none|int8|topk
    topk_frac: float = 0.01
    error_feedback: bool = True


def init_residual(params):
    return jax.tree.map(jnp.zeros_like, params)


def _int8_compress(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def _topk_mask(g, frac: float):
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_grads(grads, residual, cfg: GradCompressionConfig):
    """Returns (compressed-but-dense grads to feed the reducer, new
    residual). Dense representation keeps the psum path uniform; the
    traffic win is modelled by the roofline (int8 leaves are 1 byte)."""
    if cfg.kind == "none":
        return grads, residual

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback:
            g32 = g32 + r.astype(jnp.float32)
        if cfg.kind == "int8":
            q, scale = _int8_compress(g32)
            out = _int8_decompress(q, scale)
        elif cfg.kind == "topk":
            out = g32 * _topk_mask(g32, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        new_r = (g32 - out) if cfg.error_feedback else r
        return out.astype(g.dtype), new_r.astype(r.dtype)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))
