"""MiniCPM-2B — dense llama-like, MHA (kv=36), WSD learning-rate schedule.
[arXiv:2404.06395; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2404.06395",
)

# Trainer default for this arch: WSD (warmup-stable-decay) schedule — see
# repro/optim/optimizer.py::wsd_schedule.
SMOKE = FULL.replace(
    name="minicpm-2b-smoke",
    num_layers=2, d_model=72, num_heads=6, num_kv_heads=6, head_dim=12,
    d_ff=144, vocab_size=256,
)
