"""HuBERT-XLarge — audio encoder-only transformer (wav2vec2 arch); the CNN
feature extractor is a STUB (``input_specs`` provides frame embeddings).
[arXiv:2106.07447; unverified]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    attention="bidir",
    mlp="gelu",
    norm="layernorm",
    is_encoder=True,
    frontend="audio_stub",
    param_dtype="bfloat16",
    source="arXiv:2106.07447",
)

SMOKE = FULL.replace(
    name="hubert-xlarge-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64, param_dtype="float32",
)
