"""Granite-3-8B — dense, GQA (kv=8).
[hf:ibm-granite/granite-3.0 family; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12_800,
    vocab_size=49_155,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    source="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = FULL.replace(
    name="granite-3-8b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, param_dtype="float32",
)
