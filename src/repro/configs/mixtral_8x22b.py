"""Mixtral-8x22B — MoE (8 experts, top-2), GQA (kv=8), sliding-window attn.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

FULL = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=32_768,
    attention="sliding",
    window=4096,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    param_dtype="bfloat16",
    source="arXiv:2401.04088",
)

SMOKE = FULL.replace(
    name="mixtral-8x22b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=32,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5),
    param_dtype="float32",
)
