"""Configuration system for Galen-JAX.

Two config kinds:
  * ``ArchConfig``  — a model architecture (one per assigned arch).
  * ``ShapeConfig`` — an input-shape cell (train_4k / prefill_32k / ...).

Configs are frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False          # Arctic-style parallel dense FFN
    router_dtype: str = "float32"
    combine: str = "allreduce"            # allreduce | reduce_scatter (§Perf)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64                    # SSD head dim (P)
    expand: int = 2                       # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256                 # SSD chunked-scan block length


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture. Fields default to a dense decoder LM."""
    name: str = "dense"
    family: str = "dense"                 # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # --- attention / mixing ---
    attention: str = "causal"             # causal|bidir|sliding|none
    window: int = 4096                    # for attention == "sliding" / local layers
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # hybrid block pattern, tiled to num_layers; entries: "attn"|"rglru"|"ssm"
    block_pattern: Tuple[str, ...] = ("attn",)
    lru_width: int = 0                    # RG-LRU width (0 => d_model)

    # --- ffn ---
    mlp: str = "swiglu"                   # swiglu|geglu|gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- embeddings / norms ---
    norm: str = "rmsnorm"                 # rmsnorm|layernorm|nonparametric_ln
    tie_embeddings: bool = False
    frontend: str = "none"                # none|vision_stub|audio_stub
    frontend_len: int = 0                 # prefix positions fed by the stub
    is_encoder: bool = False              # encoder-only (no causal mask, no decode)

    # --- numerics / compile ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True              # lax.scan over a homogeneous stack
    remat: str = "none"                   # none|full|dots_saveable

    # --- bookkeeping ---
    source: str = ""                      # citation tag

    def __post_init__(self):
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixing kind, block_pattern tiled to num_layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def homogeneous(self) -> bool:
        return len(set(self.layer_kinds)) == 1

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer does full-length quadratic attention."""
        if self.attention == "sliding":
            return True
        kinds = set(self.layer_kinds)
        if "attn" in kinds and self.attention in ("causal", "bidir"):
            return False
        return True

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                             # train|prefill|decode
    # decode: one new token against a KV cache of ``seq_len``.


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; reason if skipped."""
    if arch.is_encoder and shape.mode == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (pure full-attention arch)"
    return True, ""
