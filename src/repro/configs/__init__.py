from repro.configs.base import (ALL_SHAPES, SHAPES_BY_NAME, ArchConfig,
                                MoEConfig, ShapeConfig, SSMConfig,
                                cell_supported)

__all__ = ["ArchConfig", "ShapeConfig", "MoEConfig", "SSMConfig",
           "ALL_SHAPES", "SHAPES_BY_NAME", "cell_supported"]
