"""InternVL2-2B — VLM; InternLM2-1.8B language backbone, InternViT frontend
as a STUB (``input_specs`` provides precomputed patch embeddings).
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    mlp="swiglu",
    norm="rmsnorm",
    frontend="vision_stub",
    frontend_len=256,                      # ViT patch tokens prepended
    source="arXiv:2404.16821",
)

SMOKE = FULL.replace(
    name="internvl2-2b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, frontend_len=8,
)
