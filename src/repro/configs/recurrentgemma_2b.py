"""RecurrentGemma-2B (Griffin) — hybrid: RG-LRU recurrent blocks + local
sliding-window attention in a (rec, rec, attn) pattern; GQA kv=1 (MQA).
[arXiv:2402.19427; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    attention="sliding",
    window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    mlp="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    scan_layers=False,                      # heterogeneous stack → unrolled
    source="arXiv:2402.19427",
)

SMOKE = FULL.replace(
    name="recurrentgemma-2b-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, window=16, lru_width=64,
)
