"""Snowflake Arctic-480B — MoE (128 experts, top-2) + dense residual FFN,
GQA (kv=8). [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

FULL = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual=True),
    param_dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
)

SMOKE = FULL.replace(
    name="arctic-480b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5,
                  dense_residual=True),
    param_dtype="float32",
)
