"""Qwen2-0.5B — dense, GQA (kv=2), QKV bias, tied embeddings.
[arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2407.10671",
)

SMOKE = FULL.replace(
    name="qwen2-0.5b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)
