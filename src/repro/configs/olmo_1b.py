"""OLMo-1B — dense, MHA (kv=16), non-parametric LayerNorm, no biases.
[arXiv:2402.00838; hf]"""
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    mlp="swiglu",
    norm="nonparametric_ln",
    tie_embeddings=True,
    source="arXiv:2402.00838",
)

SMOKE = FULL.replace(
    name="olmo-1b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)
