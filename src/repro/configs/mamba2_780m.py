"""Mamba2-780M — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig

FULL = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                                 # attn-free; mixing is the SSM block
    vocab_size=50_280,
    attention="none",
    block_pattern=("ssm",),
    mlp="none",
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

SMOKE = FULL.replace(
    name="mamba2-780m-smoke",
    num_layers=2, d_model=64, vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=32),
)
