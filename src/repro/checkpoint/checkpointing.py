"""Sharded, atomic, async checkpointing (fault-tolerance substrate).

Layout:  <dir>/step_<N>/
            manifest.json        — tree structure, shapes, dtypes, step,
                                   mesh shape, data-pipeline cursor, rng
            <leaf-path>.npy      — one file per pytree leaf (per-host
                                   shard slice in multi-host mode)
         <dir>/LATEST            — atomic pointer (written last)

Guarantees:
* atomicity — a checkpoint is visible only after its manifest and LATEST
  pointer land (rename(2) is atomic); a crash mid-save leaves the previous
  checkpoint intact.
* restart — ``restore_latest`` rebuilds params/opt state and returns the
  step + data cursor so training resumes bit-exact (data pipeline is a
  pure function of (seed, step)).
* elasticity — leaves are stored unsharded (gathered) or as per-host
  slices with their PartitionSpec recorded; ``restore`` re-shards onto the
  *current* mesh, so a job restarted on fewer/more hosts reloads cleanly
  (elastic re-mesh, DESIGN §4).
* async — ``save_async`` snapshots device arrays to host then writes on a
  background thread; training continues immediately.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(_path_part(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3):
    """Synchronous atomic save of a pytree."""
    tmp = os.path.join(directory, f"_tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, "_LATEST_tmp")
    with open(ptr_tmp, "w") as f:
        f.write(str(step))
    os.rename(ptr_tmp, os.path.join(directory, "LATEST"))
    _gc(directory, keep)


def _gc(directory: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot to host immediately; write in a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._thread = threading.Thread(
            target=save, args=(self.directory, step, host_tree, extra,
                               self.keep), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def save_async(checkpointer: AsyncCheckpointer, step: int, tree: Any,
               extra: Optional[dict] = None) -> None:
    """Atomic async save through a long-lived ``AsyncCheckpointer`` — the
    fleet drivers' entry point: snapshot now, write in the background, the
    previous checkpoint stays intact until the new LATEST pointer lands."""
    checkpointer.save(step, tree, extra)


def _intact_steps(directory: str) -> list[int]:
    """Steps whose dir holds a readable manifest (i.e. fully committed)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if not d.startswith("step_"):
            continue
        try:
            step = int(d.split("_", 1)[1])
        except ValueError:
            continue
        if os.path.exists(os.path.join(directory, d, "manifest.json")):
            steps.append(step)
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    """Step the LATEST pointer names — or, when the pointer is missing,
    unreadable, or DANGLING (a crash between step-dir GC and the pointer
    rewrite leaves it naming a deleted dir), the newest step with an intact
    manifest. Returns None when no intact checkpoint exists."""
    ptr = os.path.join(directory, "LATEST")
    if os.path.exists(ptr):
        try:
            with open(ptr) as f:
                step = int(f.read().strip())
        except ValueError:
            step = None
        if step is not None and os.path.exists(
                os.path.join(directory, f"step_{step}", "manifest.json")):
            return step
    steps = _intact_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore a pytree saved by ``save``; reshard onto ``shardings`` if
    given (elastic re-mesh). ``like`` provides the tree structure."""
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(like)
    flat_sh = None
    if shardings is not None:
        flat_sh, _ = _flatten(shardings)
    out = {}
    for key in flat_like:
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(d, info["file"]))
        if flat_sh is not None and key in flat_sh:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild in treedef leaf order
    leaves, _ = jax.tree_util.tree_flatten_with_path(like)
    ordered = [out["/".join(_path_part(p) for p in path)]
               for path, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]


def restore_latest(directory: str, like: Any, shardings: Any = None):
    step = latest_step(directory)
    if step is None:
        return None, None, None
    tree, extra = restore(directory, step, like, shardings)
    return tree, step, extra
