"""Fake quantization (paper Eq. 3) — asymmetric uniform, dynamic per-channel
range, straight-through estimator for QAT.

The paper's three layer modes map to effective bit widths:
    FP32 -> bits = 32 (pass-through)
    INT8 -> bits = 8
    MIX  -> bits in [1, MAX_MIX_BITS]  (weights and activations independent)

Bit widths are carried as (possibly traced) int32 scalars so a whole
compression policy can flow through a ``lax.scan`` over stacked layers; the
``bits >= 32`` pass-through is a ``jnp.where`` select, not Python control
flow. When a model is built *without* a policy the quant path is skipped
statically (zero overhead for the uncompressed dry-run).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# On-TPU truth (see DESIGN.md §1): MIX above 6 bits is never better than
# INT8 (same MXU path, worse packing), mirroring the paper's ARM finding.
MAX_MIX_BITS = 6

_fq_ops = None          # lazy kernels.ops handle (kernels import late —
                        # the kernel package must not load at model-import)


@jax.custom_jvp
def _fused_fake_quant_ste(xf: jnp.ndarray, bits) -> jnp.ndarray:
    """Kernel-backed quant-dequant with a straight-through JVP —
    ``pallas_call`` has no differentiation rule, so the identity
    tangent (exactly the STE) is attached here and ``jax.grad`` never
    traces into the kernel."""
    global _fq_ops
    if _fq_ops is None:
        from repro.kernels import ops
        _fq_ops = ops
    return _fq_ops.fused_fake_quant(xf, bits)


@_fused_fake_quant_ste.defjvp
def _fused_fake_quant_ste_jvp(primals, tangents):
    return _fused_fake_quant_ste(*primals), tangents[0]


def _kernel_route(x: jnp.ndarray, axis) -> bool:
    """True when this fake-quant call should run through the fused
    Pallas kernel (``kernels.ops.fused_fake_quant``): the kernel only
    implements the per-channel-last layout (range reduced over every
    non-final axis), and only a TPU backend compiles it to Mosaic —
    everywhere else the reference jnp path stays the default.
    ``GALEN_FQ_KERNEL=1`` forces the kernel (interpreted off-TPU, for
    parity tests); ``GALEN_FQ_KERNEL=0`` forces the reference path even
    on TPU. The route is resolved at trace time, so already-compiled
    functions keep their path."""
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    if x.ndim < 2 or tuple(axes) != tuple(range(x.ndim - 1)):
        return False
    v = os.environ.get("GALEN_FQ_KERNEL")
    if v is not None:
        return v == "1"
    return jax.default_backend() == "tpu"


def _minmax(x: jnp.ndarray, axis) -> tuple[jnp.ndarray, jnp.ndarray]:
    x_min = jnp.min(x, axis=axis, keepdims=True)
    x_max = jnp.max(x, axis=axis, keepdims=True)
    # Guard degenerate (constant) channels.
    span = jnp.maximum(x_max - x_min, 1e-8)
    return x_min, x_min + span


def quantize(x: jnp.ndarray, bits, axis) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Paper Eq. 3: Q(r) = clip(floor(s*r - z), -n, n).

    Returns (q, scale, offset); all computed in f32.
    ``axis``: reduction axes for the dynamic range (per-channel = all axes
    except the channel one).
    """
    xf = x.astype(jnp.float32)
    bits = jnp.asarray(bits, jnp.float32)
    n = 2.0 ** bits - 1.0
    x_min, x_max = _minmax(xf, axis)
    s = n / (x_max - x_min)
    z = jnp.floor(s * x_min) + 2.0 ** (bits - 1.0)
    q = jnp.clip(jnp.floor(s * xf - z), -n, n)
    return q, s, z


def dequantize(q: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    return (q + z + 0.5) / s  # +0.5: mid-rise reconstruction of the floor


def fake_quant(x: jnp.ndarray, bits, axis=None) -> jnp.ndarray:
    """Quantize-dequantize with straight-through gradients.

    ``bits`` may be a traced int scalar; bits >= 32 selects pass-through.
    ``axis=None`` -> per-channel over the LAST axis (paper: per channel).
    """
    if axis is None:
        axis = tuple(range(x.ndim - 1))
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    if _kernel_route(x, axis):
        # one-pass fused minmax/quant/dequant (bits >= 32 selects
        # pass-through inside the kernel)
        xq = _fused_fake_quant_ste(xf, jnp.asarray(bits, jnp.int32))
    else:
        q, s, z = quantize(xf, jnp.clip(jnp.asarray(bits), 1, 31), axis)
        xq = dequantize(q, s, z)
        xq = jnp.where(jnp.asarray(bits) >= 32, xf, xq)
    # Straight-through estimator: forward quantized values, identity grad.
    out = xf + jax.lax.stop_gradient(xq - xf)
    return out.astype(orig_dtype)


def fake_quant_weight(w: jnp.ndarray, bits) -> jnp.ndarray:
    """Weights: per-OUTPUT-channel range (last axis is the out dim here)."""
    return fake_quant(w, bits, axis=tuple(range(w.ndim - 1)))


def fake_quant_act(x: jnp.ndarray, bits) -> jnp.ndarray:
    """Activations: per-channel over the feature (last) axis."""
    return fake_quant(x, bits, axis=tuple(range(x.ndim - 1)))


def bits_for_mode(mode: str, mix_bits: int = MAX_MIX_BITS) -> int:
    return {"FP32": 32, "INT8": 8, "MIX": mix_bits}[mode]
