"""Agent state construction (paper Fig. 2: model features X_t -> s_t).

Features per time step (one compressible unit): position, unit kind,
dimensions, FLOPs/weight shares, sensitivity probes, previous action, and
latency-budget bookkeeping under the partial policy (AMC's reduced/rest
features, computed against the hardware latency oracle instead of FLOPs).

``prev_action`` (and hence ``state_dim``) is sized by the agent's
``action_dim``, which may be padded above the method's native count so
mixed-method members of a ``PopulationSearch`` share one vmappable shape
(trailing entries stay zero/inert for single-method agents).

Three builders share the feature definitions: ``build_state`` (scalar),
``build_state_batch`` (K episodes, numpy), and ``StateTables`` +
``fused_state_block`` (the fused rollout scan: per-step constants
precomputed from the same ``_static_features`` cache, the
decided-latency share computed in-scan from the traceable oracle).
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.latency import (HardwareTarget, LatencyContext,
                                PolicyLatency, fifo_cached, policy_latency)
from repro.core.policy import Policy
from repro.core.sensitivity import FEATURE_PROBES, SensitivityResult
from repro.core.spec import LayerSpec

KINDS = ("conv", "attn_qkv", "attn_out", "mlp_up", "mlp_down", "moe_up",
         "moe_down", "ssm_in", "ssm_out", "rglru_in", "rglru_out", "embed",
         "head")


def state_dim(action_dim: int) -> int:
    return (1 + len(KINDS) + 3 + 2 + 2 + len(FEATURE_PROBES)
            + action_dim + 3)


def build_state(specs: Sequence[LayerSpec], t: int, partial: Policy,
                sens: SensitivityResult, prev_action: np.ndarray,
                hw: HardwareTarget, ctx: LatencyContext,
                ref_lat: PolicyLatency, window: int = 0) -> np.ndarray:
    static, this_share, rest_share, ref_total = _static_features(
        specs, t, sens, ref_lat)
    cur = policy_latency(specs, partial, hw, ctx, window)
    # latency of units decided so far (indices < t) under partial policy
    # vs what remains at reference cost; policy_latency may interleave
    # attention-extra entries, so map each unit back by name
    decided = sum(u.time_s for u in cur.units
                  if _unit_index(u.name, specs) < t)
    tail = np.asarray([this_share, decided / ref_total, rest_share],
                      np.float32)
    return np.concatenate([static,
                           np.asarray(prev_action, np.float32).ravel(),
                           tail])


def build_state_batch(specs: Sequence[LayerSpec], t: int, cur_lat,
                      sens: SensitivityResult, prev_actions: np.ndarray,
                      ref_lat: PolicyLatency) -> np.ndarray:
    """Batched ``build_state``: one (K, state_dim) array for K episodes.

    ``cur_lat`` is a ``BatchedPolicyLatency`` for the K partial policies
    (the caller already evaluates the vectorized oracle each step, so
    the per-step scalar oracle sweep is not repeated here). All features
    except ``prev_action`` and the decided-latency share are identical
    across the batch and cached per (specs, sens, ref_lat, t).
    """
    static, this_share, rest_share, ref_total = _static_features(
        specs, t, sens, ref_lat)
    prev_actions = np.atleast_2d(np.asarray(prev_actions, np.float32))
    K = prev_actions.shape[0]
    decided = (cur_lat.decided_before(t) / ref_total).astype(np.float32)
    tail = np.column_stack([
        np.full(K, this_share, np.float32), decided,
        np.full(K, rest_share, np.float32)])
    return np.concatenate([np.tile(static, (K, 1)), prev_actions, tail],
                          axis=1)


_static_cache: dict = {}
_STATIC_CACHE_MAX = 4096               # ~entries for dozens of searches


def _static_features(specs, t, sens, ref_lat):
    hit = fifo_cached(
        _static_cache, _STATIC_CACHE_MAX, (id(specs), id(sens),
                                           id(ref_lat), t),
        lambda h: h[0] is specs and h[1] is sens and h[2] is ref_lat,
        lambda: (specs, sens, ref_lat,
                 _compute_static_features(specs, t, sens, ref_lat)))
    return hit[3]


def _compute_static_features(specs, t, sens, ref_lat):
    s = specs[t]
    total_flops = sum(x.flops_per_token for x in specs) or 1.0
    total_weights = sum(x.weight_elems for x in specs) or 1.0
    feats = [t / max(1, len(specs))]
    feats += [1.0 if s.kind == k else 0.0 for k in KINDS]
    feats += [np.log1p(s.in_dim) / 12.0, np.log1p(s.out_dim) / 12.0,
              np.log1p(s.prune_dim) / 12.0]
    feats += [s.flops_per_token / total_flops,
              s.weight_elems / total_weights]
    feats += [1.0 if s.prunable else 0.0, 1.0 if s.mix_supported else 0.0]
    # array-form probe row (log1p KLs; MISSING_KL sentinel where a probe
    # was not run — legality-aware, see SensitivityResult.feature_row)
    static = np.concatenate([np.asarray(feats, np.float32),
                             sens.feature_row(s.name)])
    ref_total = ref_lat.total_s or 1.0
    this_share = sum(u.time_s for u in ref_lat.units
                     if _unit_index(u.name, specs) == t) / ref_total
    rest_share = sum(u.time_s for u in ref_lat.units
                     if _unit_index(u.name, specs) >= t) / ref_total
    return (static, this_share, rest_share, ref_total)


class StateTables:
    """Per-step state-feature constants for the fused rollout scan.

    Everything in ``build_state_batch`` that does not depend on the
    partial policy, laid out per scan step (one row per actionable
    unit): the static feature block, the reference-latency shares, and
    the spec index used for the in-scan decided-latency mask. Values
    come from the same ``_static_features`` cache the numpy engines
    read, so the two paths agree bit-for-bit on these features.

    ``this_share``/``rest_share``/``ref_total`` derive from ``ref_lat``
    and hence from the hardware target — the fused rollout takes them as
    (vmappable) arguments, while ``static`` is target-independent and
    bakes into the trace.
    """

    def __init__(self, specs, steps, sens, ref_lat):
        rows, this_s, rest_s = [], [], []
        ref_total = 1.0
        for t in steps:
            static, a, b, ref_total = _static_features(specs, t, sens,
                                                       ref_lat)
            rows.append(static)
            this_s.append(a)
            rest_s.append(b)
        self.static = np.stack(rows).astype(np.float32)      # (T, S)
        self.shares = np.stack(                              # (T, 2)
            [np.asarray(this_s, np.float32),
             np.asarray(rest_s, np.float32)], axis=1)
        self.ref_total = float(ref_total)
        self.spec_idx = np.asarray(steps, np.int32)          # (T,)


def fused_state_block(static_row, shares_row, decided, prev_actions):
    """One scan step's (K, state_dim) block: the traced twin of
    ``build_state_batch`` given precomputed ``StateTables`` rows and the
    in-scan decided-latency share."""
    K = prev_actions.shape[0]
    static = jnp.broadcast_to(static_row, (K,) + static_row.shape)
    tail = jnp.stack([jnp.broadcast_to(shares_row[0], (K,)), decided,
                      jnp.broadcast_to(shares_row[1], (K,))], axis=1)
    return jnp.concatenate([static, prev_actions, tail], axis=1)


_name_cache: dict = {}


def _unit_index(unit_name: str, specs: Sequence[LayerSpec]) -> int:
    key = id(specs)
    hit = _name_cache.get(key)
    # identity-guard + strong ref, so a recycled list id cannot serve a
    # stale table (same idiom as _static_cache / the oracle cache)
    if hit is None or hit[0] is not specs:
        hit = (specs, {s.name: i for i, s in enumerate(specs)})
        _name_cache[key] = hit
    base = unit_name[:-5] if unit_name.endswith(".attn") else unit_name
    return hit[1].get(base, len(specs))
