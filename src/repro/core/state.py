"""Agent state construction (paper Fig. 2: model features X_t -> s_t).

Features per time step (one compressible unit): position, unit kind,
dimensions, FLOPs/weight shares, sensitivity probes, previous action, and
latency-budget bookkeeping under the partial policy (AMC's reduced/rest
features, computed against the hardware latency oracle instead of FLOPs).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.latency import (HardwareTarget, LatencyContext,
                                PolicyLatency, policy_latency)
from repro.core.policy import Policy
from repro.core.sensitivity import SensitivityResult
from repro.core.spec import LayerSpec

KINDS = ("conv", "attn_qkv", "attn_out", "mlp_up", "mlp_down", "moe_up",
         "moe_down", "ssm_in", "ssm_out", "rglru_in", "rglru_out", "embed",
         "head")


def state_dim(action_dim: int) -> int:
    return 1 + len(KINDS) + 3 + 2 + 2 + 6 + action_dim + 3


def build_state(specs: Sequence[LayerSpec], t: int, partial: Policy,
                sens: SensitivityResult, prev_action: np.ndarray,
                hw: HardwareTarget, ctx: LatencyContext,
                ref_lat: PolicyLatency, window: int = 0) -> np.ndarray:
    s = specs[t]
    total_flops = sum(x.flops_per_token for x in specs) or 1.0
    total_weights = sum(x.weight_elems for x in specs) or 1.0

    kind_onehot = [1.0 if s.kind == k else 0.0 for k in KINDS]

    cur = policy_latency(specs, partial, hw, ctx, window)
    ref_total = ref_lat.total_s or 1.0
    # latency of units decided so far (indices < t) under partial policy
    # vs what remains at reference cost
    per_unit = [u.time_s for u in cur.units]
    # policy_latency may interleave attention-extra entries; map by name
    decided = sum(u.time_s for u in cur.units
                  if _unit_index(u.name, specs) < t)
    rest_ref = sum(u.time_s for u in ref_lat.units
                   if _unit_index(u.name, specs) >= t)
    this_share = sum(u.time_s for u in ref_lat.units
                     if _unit_index(u.name, specs) == t) / ref_total

    feats: List[float] = [t / max(1, len(specs))]
    feats += kind_onehot
    feats += [np.log1p(s.in_dim) / 12.0, np.log1p(s.out_dim) / 12.0,
              np.log1p(s.prune_dim) / 12.0]
    feats += [s.flops_per_token / total_flops,
              s.weight_elems / total_weights]
    feats += [1.0 if s.prunable else 0.0, 1.0 if s.mix_supported else 0.0]
    feats += sens.features_for(s.name)
    feats += list(np.asarray(prev_action, np.float32))
    feats += [this_share, decided / ref_total, rest_ref / ref_total]
    return np.asarray(feats, np.float32)


_name_cache: dict = {}


def _unit_index(unit_name: str, specs: Sequence[LayerSpec]) -> int:
    key = id(specs)
    table = _name_cache.get(key)
    if table is None:
        table = {s.name: i for i, s in enumerate(specs)}
        _name_cache[key] = table
    base = unit_name[:-5] if unit_name.endswith(".attn") else unit_name
    return table.get(base, len(specs))
