"""Structured pruning — ℓ1 channel selection (Li et al. 2017, paper §Pruning).

Given a weight (or a group of weights sharing an output dim) and a kept
count, produce a float 0/1 mask keeping the channels with the largest ℓ1
norms. During search the mask multiplies activations (identical accuracy
effect to removal, static shapes — see DESIGN.md §3); deployment slices.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np


def l1_scores(ws: Sequence[jnp.ndarray], axis: int = -1) -> jnp.ndarray:
    """Sum of ℓ1 norms over every weight in the group, reduced to the
    channel axis (default: last = output channels)."""
    total = None
    for w in ws:
        red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
        s = jnp.sum(jnp.abs(w.astype(jnp.float32)), axis=red)
        total = s if total is None else total + s
    return total


def keep_mask(scores: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Float mask keeping the ``keep`` highest-scoring channels."""
    n = scores.shape[0]
    keep = int(np.clip(keep, 0, n))
    if keep >= n:
        return jnp.ones((n,), jnp.float32)
    if keep == 0:
        return jnp.zeros((n,), jnp.float32)
    thresh = jnp.sort(scores)[n - keep]
    mask = (scores >= thresh).astype(jnp.float32)
    # Ties could keep too many — break deterministically by index order.
    excess = jnp.cumsum(mask) > keep
    return jnp.where(excess, 0.0, mask)


def keep_mask_dynamic(scores: jnp.ndarray, keep) -> jnp.ndarray:
    """``keep_mask`` with a *traced* kept count (jit/vmap-safe).

    Bit-for-bit the same selection as ``keep_mask`` — threshold at the
    keep-th largest score, then drop later-indexed ties past the count —
    but ``keep`` may be a traced int32 scalar, so one compilation serves
    every policy in a batch.
    """
    n = scores.shape[0]
    keep = jnp.clip(keep, 0, n)
    thresh = jnp.sort(scores)[jnp.clip(n - keep, 0, n - 1)]
    mask = (scores >= thresh).astype(jnp.float32)
    mask = jnp.where(jnp.cumsum(mask) > keep, 0.0, mask)
    return jnp.where(keep > 0, mask, jnp.zeros_like(mask))


def head_scores(wq: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """ℓ1 score per attention head from wq [d, H*hd]."""
    d, hhd = wq.shape
    hd = hhd // num_heads
    w = jnp.abs(wq.astype(jnp.float32)).reshape(d, num_heads, hd)
    return jnp.sum(w, axis=(0, 2))


def slice_indices(mask: jnp.ndarray) -> np.ndarray:
    """Indices of kept channels (host-side; used when materializing the
    deployed, truly-sliced model)."""
    return np.nonzero(np.asarray(mask) > 0)[0]
