"""Reward functions.

Primary: the *absolute reward* (Bender et al. 2020) used by the paper
(Eq. 6):   r(P) = acc + β · | T_P / (c · T_ref) − 1 |,  β < 0.

Also provided: the hard-exponential reward (MnasNet) the paper tried and
rejected — kept for the ablation benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RewardConfig:
    target_ratio: float = 0.3          # c — target latency fraction
    beta: float = -3.0                 # cost exponent (paper: -3.0)
    kind: str = "absolute"             # absolute|hard_exponential


def absolute_reward(acc: float, latency: float, ref_latency: float,
                    c: float, beta: float = -3.0) -> float:
    return acc + beta * abs(latency / (c * ref_latency) - 1.0)


def hard_exponential_reward(acc: float, latency: float, ref_latency: float,
                            c: float, beta: float = -0.07) -> float:
    """MnasNet-style: acc * (T/T_target)^beta, only penalizing overshoot."""
    ratio = latency / (c * ref_latency)
    return acc * (ratio ** beta if ratio > 1.0 else 1.0)


def compute_reward(cfg: RewardConfig, acc: float, latency: float,
                   ref_latency: float) -> float:
    if cfg.kind == "absolute":
        return absolute_reward(acc, latency, ref_latency, cfg.target_ratio,
                               cfg.beta)
    if cfg.kind == "hard_exponential":
        return hard_exponential_reward(acc, latency, ref_latency,
                                       cfg.target_ratio)
    raise ValueError(cfg.kind)
