"""Reward functions.

Primary: the *absolute reward* (Bender et al. 2020) used by the paper
(Eq. 6):   r(P) = acc + β · | T_P / (c · T_ref) − 1 |,  β < 0.

Also provided: the hard-exponential reward (MnasNet) the paper tried and
rejected — kept for the ablation benchmark.

``compute_reward`` is the scalar host path; ``compute_reward_batch`` is
the same math over (K,) arrays in jnp, usable inside jitted code (the
fused rollout engine) and on host arrays alike.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class RewardConfig:
    target_ratio: float = 0.3          # c — target latency fraction
    beta: float = -3.0                 # cost exponent (paper: -3.0)
    kind: str = "absolute"             # absolute|hard_exponential
    hard_beta: float = -0.07           # exponent for kind="hard_exponential"
                                       # (MnasNet's -0.07; separate from
                                       # ``beta`` — the absolute reward's
                                       # -3.0 would be far too steep here)


def absolute_reward(acc: float, latency: float, ref_latency: float,
                    c: float, beta: float = -3.0) -> float:
    return acc + beta * abs(latency / (c * ref_latency) - 1.0)


def hard_exponential_reward(acc: float, latency: float, ref_latency: float,
                            c: float, beta: float = -0.07) -> float:
    """MnasNet-style: acc * (T/T_target)^beta, only penalizing overshoot."""
    ratio = latency / (c * ref_latency)
    return acc * (ratio ** beta if ratio > 1.0 else 1.0)


def compute_reward(cfg: RewardConfig, acc: float, latency: float,
                   ref_latency: float) -> float:
    if cfg.kind == "absolute":
        return absolute_reward(acc, latency, ref_latency, cfg.target_ratio,
                               cfg.beta)
    if cfg.kind == "hard_exponential":
        return hard_exponential_reward(acc, latency, ref_latency,
                                       cfg.target_ratio, cfg.hard_beta)
    raise ValueError(cfg.kind)


def compute_reward_batch(cfg: RewardConfig, acc, latency, ref_latency,
                         xp=jnp):
    """``compute_reward`` over (K,) arrays. Traceable with the default
    ``xp=jnp`` (the fused/epoch engines); the numpy engines pass
    ``xp=np`` to keep their record tail off the device."""
    ratio = latency / (cfg.target_ratio * ref_latency)
    if cfg.kind == "absolute":
        return acc + cfg.beta * xp.abs(ratio - 1.0)
    if cfg.kind == "hard_exponential":
        return acc * xp.where(ratio > 1.0, ratio ** cfg.hard_beta, 1.0)
    raise ValueError(cfg.kind)
