"""Replay buffers for the DDPG agents (paper: size 2000 transitions).

Two implementations with the same ring semantics:

  * ``ReplayBuffer``  — host-side numpy buffer. The original (and
    reference) implementation; still used by tests and by callers that
    sample on the host.
  * ``DeviceReplay``  — device-resident ring buffer whose storage is a
    ``DeviceReplayData`` pytree of fixed-size jnp arrays. Pushes are one
    jitted ring write; sampling is a pure function
    (``device_replay_sample``) that also runs *inside* the fused
    ``update_chunk`` scan (core/ddpg.py), so a whole block of agent
    updates needs zero host round-trips for batch assembly.

Both write incoming transitions at ``(ptr + i) % capacity`` and sample
uniformly over the filled prefix, so the host buffer doubles as the
property-test reference for the device one.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.states = np.zeros((capacity, state_dim), np.float32)
        self.actions = np.zeros((capacity, action_dim), np.float32)
        self.rewards = np.zeros((capacity,), np.float32)
        self.next_states = np.zeros((capacity, state_dim), np.float32)
        self.dones = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)

    def push(self, s, a, r, s_next, done):
        i = self.ptr
        self.states[i] = s
        self.actions[i] = a
        self.rewards[i] = r
        self.next_states[i] = s_next
        self.dones[i] = float(done)
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def push_batch(self, s, a, r, s_next, done):
        """Bulk insert N transitions in one vectorized ring write."""
        s = np.asarray(s, np.float32)
        n = s.shape[0]
        if n == 0:
            return
        if n >= self.capacity:
            # oversized batch: only the last `capacity` rows survive; they
            # land where sequential pushes would have left them, i.e. row
            # n-1 at slot (ptr + n - 1) % capacity
            a = np.asarray(a, np.float32)[n - self.capacity:]
            r = np.asarray(r, np.float32)[n - self.capacity:]
            s_next = np.asarray(s_next, np.float32)[n - self.capacity:]
            done = np.asarray(done, np.float32)[n - self.capacity:]
            s = s[n - self.capacity:]
            idx = (self.ptr + n - self.capacity
                   + np.arange(self.capacity)) % self.capacity
        else:
            idx = (self.ptr + np.arange(n)) % self.capacity
            a = np.asarray(a, np.float32)
            r = np.asarray(r, np.float32)
            s_next = np.asarray(s_next, np.float32)
            done = np.asarray(done, np.float32)
        self.states[idx] = s
        self.actions[idx] = a
        self.rewards[idx] = r
        self.next_states[idx] = s_next
        self.dones[idx] = done
        self.ptr = int((self.ptr + n) % self.capacity)
        self.size = int(min(self.size + n, self.capacity))

    def sample(self, batch: int):
        idx = self.rng.integers(0, self.size, size=batch)
        return (self.states[idx], self.actions[idx], self.rewards[idx],
                self.next_states[idx], self.dones[idx])

    def __len__(self):
        return self.size


# ===========================================================================
# Device-resident replay
# ===========================================================================

class DeviceReplayData(NamedTuple):
    """The pytree form of the ring buffer — what jitted code consumes.

    ``ptr``/``size`` are 0-d int32 arrays so the whole tuple vmaps over
    a stacked population of buffers.
    """
    states: jnp.ndarray        # (capacity, state_dim)
    actions: jnp.ndarray       # (capacity, action_dim)
    rewards: jnp.ndarray       # (capacity,)
    next_states: jnp.ndarray   # (capacity, state_dim)
    dones: jnp.ndarray         # (capacity,)
    ptr: jnp.ndarray           # () int32
    size: jnp.ndarray          # () int32


def device_replay_init(capacity: int, state_dim: int,
                       action_dim: int) -> DeviceReplayData:
    return DeviceReplayData(
        states=jnp.zeros((capacity, state_dim), jnp.float32),
        actions=jnp.zeros((capacity, action_dim), jnp.float32),
        rewards=jnp.zeros((capacity,), jnp.float32),
        next_states=jnp.zeros((capacity, state_dim), jnp.float32),
        dones=jnp.zeros((capacity,), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32))


def device_replay_push(data: DeviceReplayData, s, a, r, s2,
                       d) -> DeviceReplayData:
    """Pure ring write of n transitions (n static from the operand
    shapes; ``ptr``/``size`` bookkeeping is carried in the pytree, so
    the write is scan-safe — the epoch engine chains E of these as
    carry transitions). Oversized batches keep only the last
    ``capacity`` rows, landing where sequential pushes would have left
    them (the ``ReplayBuffer.push_batch`` reference semantics)."""
    capacity = data.states.shape[0]
    n = s.shape[0]
    if n == 0:
        return data
    if n >= capacity:
        s, a, r, s2, d = (x[n - capacity:] for x in (s, a, r, s2, d))
    m = s.shape[0]
    # slot of the first surviving row under sequential-push semantics
    start = (data.ptr + (n - m)) % capacity
    idx = (start + jnp.arange(m)) % capacity
    return DeviceReplayData(
        states=data.states.at[idx].set(s),
        actions=data.actions.at[idx].set(a),
        rewards=data.rewards.at[idx].set(r),
        next_states=data.next_states.at[idx].set(s2),
        dones=data.dones.at[idx].set(d),
        ptr=((data.ptr + n) % capacity).astype(jnp.int32),
        size=jnp.minimum(data.size + n, capacity).astype(jnp.int32))


_device_push = jax.jit(device_replay_push)


def device_replay_sample(data: DeviceReplayData, key, batch: int):
    """Uniform sample of `batch` transitions (pure; scan-safe)."""
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(data.size, 1))
    return (data.states[idx], data.actions[idx], data.rewards[idx],
            data.next_states[idx], data.dones[idx])


_sample_jit = jax.jit(device_replay_sample, static_argnums=(2,))


class DeviceReplay:
    """Host shim over ``DeviceReplayData`` with the ``ReplayBuffer`` API.

    ``ptr``/``size`` are mirrored on the host so ``len()`` and the
    ``size >= batch_size`` update gate never synchronize the device.
    ``data`` is handed directly to ``update_chunk`` /
    ``population_update_chunk`` for in-scan sampling.
    """

    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.state_dim = state_dim
        self.action_dim = action_dim
        self.data = device_replay_init(capacity, state_dim, action_dim)
        self.ptr = 0
        self.size = 0
        self._key = jax.random.PRNGKey(seed)

    def push(self, s, a, r, s_next, done):
        self.push_batch(np.asarray(s, np.float32)[None],
                        np.asarray(a, np.float32)[None],
                        np.asarray([r], np.float32),
                        np.asarray(s_next, np.float32)[None],
                        np.asarray([float(done)], np.float32))

    def push_batch(self, s, a, r, s_next, done):
        s = np.asarray(s, np.float32)
        n = s.shape[0]
        if n == 0:
            return
        a = np.asarray(a, np.float32)
        r = np.asarray(r, np.float32)
        s_next = np.asarray(s_next, np.float32)
        done = np.asarray(done, np.float32)
        data = self.data
        if n >= self.capacity:
            # trim to the surviving tail on the host — no oversized
            # transfer, one compiled form for every oversized n; the
            # pre-advanced ptr lands the tail (and the final ptr) where
            # sequential pushes would
            cut = n - self.capacity
            s, a, r = s[cut:], a[cut:], r[cut:]
            s_next, done = s_next[cut:], done[cut:]
            data = data._replace(ptr=jnp.asarray(
                (self.ptr + cut) % self.capacity, jnp.int32))
        # host mirrors advance without touching the device values
        self.ptr = int((self.ptr + n) % self.capacity)
        self.size = int(min(self.size + n, self.capacity))
        self.data = _device_push(data, s, a, r, s_next, done)

    def adopt(self, data: DeviceReplayData, pushed: int):
        """Take a post-dispatch ring as truth after ``pushed`` transitions
        were written device-side (the epoch engine's path); the host
        ptr/size mirrors advance arithmetically, never syncing."""
        self.data = data
        self.ptr = int((self.ptr + pushed) % self.capacity)
        self.size = int(min(self.size + pushed, self.capacity))

    def load(self, data: DeviceReplayData, ptr: int, size: int):
        """Adopt a RESTORED ring (checkpoint resume): contents come from the
        checkpoint tree, host ptr/size mirrors from the manifest — resuming
        preserves both the sampleable prefix and the next write slot, so the
        update schedule and ring writes continue bit-exact."""
        self.data = data
        self.ptr = int(ptr) % self.capacity
        self.size = min(int(size), self.capacity)

    def sample(self, batch: int):
        """Host-visible uniform sample (compat path + determinism tests).

        Draws from the same jax PRNG stream per instance: same seed +
        same pushes -> same sample sequence.
        """
        self._key, k = jax.random.split(self._key)
        out = _sample_jit(self.data, k, batch)
        return tuple(np.asarray(x) for x in out)

    def __len__(self):
        return self.size
