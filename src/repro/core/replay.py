"""Replay buffer for the DDPG agents (paper: size 2000 transitions)."""
from __future__ import annotations

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.states = np.zeros((capacity, state_dim), np.float32)
        self.actions = np.zeros((capacity, action_dim), np.float32)
        self.rewards = np.zeros((capacity,), np.float32)
        self.next_states = np.zeros((capacity, state_dim), np.float32)
        self.dones = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)

    def push(self, s, a, r, s_next, done):
        i = self.ptr
        self.states[i] = s
        self.actions[i] = a
        self.rewards[i] = r
        self.next_states[i] = s_next
        self.dones[i] = float(done)
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def push_batch(self, s, a, r, s_next, done):
        """Bulk insert N transitions in one vectorized ring write."""
        s = np.asarray(s, np.float32)
        n = s.shape[0]
        if n == 0:
            return
        if n >= self.capacity:
            # degenerate oversized batch: only the tail survives anyway
            for i in range(n):
                self.push(s[i], a[i], r[i], s_next[i], done[i])
            return
        idx = (self.ptr + np.arange(n)) % self.capacity
        self.states[idx] = s
        self.actions[idx] = np.asarray(a, np.float32)
        self.rewards[idx] = np.asarray(r, np.float32)
        self.next_states[idx] = np.asarray(s_next, np.float32)
        self.dones[idx] = np.asarray(done, np.float32)
        self.ptr = int((self.ptr + n) % self.capacity)
        self.size = int(min(self.size + n, self.capacity))

    def sample(self, batch: int):
        idx = self.rng.integers(0, self.size, size=batch)
        return (self.states[idx], self.actions[idx], self.rewards[idx],
                self.next_states[idx], self.dones[idx])

    def __len__(self):
        return self.size
