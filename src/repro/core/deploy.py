"""Deployment-mode quantization: store weights in integer containers.

The search (core/search.py) evaluates ACCURACY with fake quant; deployment
materializes the winning policy as real int8 / packed-int4 weights so the
HBM/ICI traffic shrinks on the actual serving path (the quantity the
latency oracle promised). Layer code (models/layers.py::materialize_weight)
dequantizes on the fly — on TPU this fuses into the consuming matmul, and
the full int8 MXU path is available through kernels/quant_matmul.py.

Weight container formats (contraction axis = -2, always even here since
every dim is a multiple of 128):
    {"w":  bf16/f32 [..., in, out]}                       — uncompressed
    {"w_q": int8 [..., in, out],   "w_scale": [..., 1, out]}  — int8
    {"w_p": int8 [..., in//2, out],"w_scale": [..., 1, out]}  — int4 packed
Scales are per-out-channel (and per-expert for stacked MoE weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_weight(w: jnp.ndarray, bits: int) -> dict:
    """Symmetric integer quantization along the contraction axis (-2)."""
    wf = w.astype(jnp.float32)
    absmax = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2, keepdims=True), 1e-8)
    if bits <= 4:
        scale = absmax / 7.0
        q = jnp.clip(jnp.round(wf / scale), -8, 7).astype(jnp.int8)
        lo = q[..., 0::2, :].astype(jnp.uint8) & 0xF
        hi = (q[..., 1::2, :].astype(jnp.uint8) & 0xF) << 4
        return {"w_p": (lo | hi).astype(jnp.int8),
                "w_scale": scale.astype(jnp.float32)}
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(wf / scale), -128, 127).astype(jnp.int8)
    return {"w_q": q, "w_scale": scale.astype(jnp.float32)}


def unpack_int4_weight(packed: jnp.ndarray) -> jnp.ndarray:
    """[..., K//2, N] -> [..., K, N] int8 in [-8, 7] (row 2i = low nibble)."""
    low = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    high = jnp.right_shift(packed, 4)
    stacked = jnp.stack([low, high], axis=-2)          # [..., K//2, 2, N]
    shp = packed.shape[:-2] + (2 * packed.shape[-2], packed.shape[-1])
    return stacked.reshape(shp).astype(jnp.int8)


RAW_WEIGHT_NAMES = ("w_up", "w_gate", "w_down", "dense_w_up",
                    "dense_w_gate", "dense_w_down", "in_proj", "out_proj",
                    "w_x", "w_y", "w_out", "embed", "unembed")


def quantize_params_for_deploy(params, bits: int = 8,
                               raw_names=RAW_WEIGHT_NAMES):
    """Convert every matmul weight in a params pytree to integer storage.
    Handles ``{"w": ...}`` linear dicts, raw named arrays (MoE weights,
    embeddings), and scan-stacked leading layer axes."""

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) >= 2:
                out = {k: v for k, v in node.items() if k != "w"}
                out.update(quantize_weight(node["w"], bits))
                return out
            out = {}
            for k, v in node.items():
                if k in raw_names and getattr(v, "ndim", 0) >= 2 \
                        and v.shape[-2] % 2 == 0:
                    out[k] = quantize_weight(v, bits)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)


def deployed_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)
               if hasattr(x, "dtype"))
