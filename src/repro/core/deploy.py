"""Deployment-mode quantization: store weights in integer containers.

The search (core/search.py) evaluates ACCURACY with fake quant; deployment
materializes the winning policy as real int8 / packed-int4 weights so the
HBM/ICI traffic shrinks on the actual serving path (the quantity the
latency oracle promised). Layer code (models/layers.py::materialize_weight)
dequantizes on the fly — on TPU this fuses into the consuming matmul, and
the full int8 MXU path is available through kernels/quant_matmul.py.

Weight container formats (contraction axis = -2, always even here since
every dim is a multiple of 128):
    {"w":  bf16/f32 [..., in, out]}                       — uncompressed
    {"w_q": int8 [..., in, out],   "w_scale": [..., 1, out]}  — int8
    {"w_p": int8 [..., in//2, out],"w_scale": [..., 1, out]}  — int4 packed
Scales are per-out-channel (and per-expert for stacked MoE weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize_weight(w: jnp.ndarray, bits: int) -> dict:
    """Symmetric integer quantization along the contraction axis (-2).

    ``bits`` must be a Python int in [2, 8]. The grid honors the ASKED
    width — ``2**(bits-1) - 1`` positive levels — so 3- and 2-bit
    requests are not silently upgraded to the int4 grid; ``bits <= 4``
    ships in the packed-int4 container, 5..8 in the int8 one. Codes are
    clipped symmetrically to [-qmax, qmax]: the ``-2**(bits-1)`` code is
    never emitted, so a dequantized weight can never overshoot the
    symmetric ±absmax range by one scale step.
    """
    if isinstance(bits, bool) or not isinstance(bits, (int, np.integer)) \
            or not 2 <= int(bits) <= 8:
        raise ValueError(
            f"quantize_weight: bits must be an int in [2, 8], got {bits!r}"
            " (FP32 layers keep their raw container; 1-bit deployment"
            " is unsupported)")
    bits = int(bits)
    if bits <= 4 and w.shape[-2] % 2 != 0:
        raise ValueError(
            f"quantize_weight: packed int4 needs an even contraction dim, "
            f"got shape {tuple(w.shape)}")
    qmax = float(2 ** (bits - 1) - 1)
    wf = w.astype(jnp.float32)
    absmax = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2, keepdims=True), 1e-8)
    scale = absmax / qmax
    q = jnp.clip(jnp.round(wf / scale), -qmax, qmax).astype(jnp.int8)
    if bits <= 4:
        lo = q[..., 0::2, :].astype(jnp.uint8) & 0xF
        hi = (q[..., 1::2, :].astype(jnp.uint8) & 0xF) << 4
        return {"w_p": (lo | hi).astype(jnp.int8),
                "w_scale": scale.astype(jnp.float32)}
    return {"w_q": q, "w_scale": scale.astype(jnp.float32)}


def unpack_int4_weight(packed: jnp.ndarray) -> jnp.ndarray:
    """[..., K//2, N] -> [..., K, N] int8 in [-8, 7] (row 2i = low nibble)."""
    low = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    high = jnp.right_shift(packed, 4)
    stacked = jnp.stack([low, high], axis=-2)          # [..., K//2, 2, N]
    shp = packed.shape[:-2] + (2 * packed.shape[-2], packed.shape[-1])
    return stacked.reshape(shp).astype(jnp.int8)


RAW_WEIGHT_NAMES = ("w_up", "w_gate", "w_down", "dense_w_up",
                    "dense_w_gate", "dense_w_down", "in_proj", "out_proj",
                    "w_x", "w_y", "w_out", "embed", "unembed")


def quantize_params_for_deploy(params, bits: int = 8,
                               raw_names=RAW_WEIGHT_NAMES,
                               bits_for=None):
    """Convert every matmul weight in a params pytree to integer storage.
    Handles ``{"w": ...}`` linear dicts, raw named arrays (MoE weights,
    embeddings), and scan-stacked leading layer axes.

    ``bits_for``: optional callable ``name -> int | None`` giving a
    per-weight width keyed by the weight's name (the enclosing dict key
    for ``{"w": ...}`` linear containers, the array's own key for raw
    named weights). ``None`` or a value > 8 keeps that weight raw;
    otherwise the value overrides the uniform ``bits``. This is how
    core/measure.py deploys a per-unit-kind search policy.
    """

    def resolve(name):
        if bits_for is None:
            return bits
        b = bits_for(name)
        if b is None or b > 8:
            return None
        return max(2, int(b))

    def walk(node, name=""):
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) >= 2:
                b = resolve(name)
                # odd contraction dims cannot pack 2/byte — keep raw,
                # same rule as the raw_names branch below
                if b is not None and (b > 4 or node["w"].shape[-2] % 2 == 0):
                    out = {k: v for k, v in node.items() if k != "w"}
                    out.update(quantize_weight(node["w"], b))
                    return out
                return dict(node)
            out = {}
            for k, v in node.items():
                b = resolve(k)
                if k in raw_names and getattr(v, "ndim", 0) >= 2 \
                        and b is not None \
                        and (b > 4 or v.shape[-2] % 2 == 0):
                    out[k] = quantize_weight(v, b)
                else:
                    out[k] = walk(v, k)
            return out
        if isinstance(node, list):
            return [walk(v, name) for v in node]
        return node

    return walk(params)


def deployed_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)
               if hasattr(x, "dtype"))
