"""Hardware latency oracle — the TPU stand-in for the paper's
compile-and-measure loop (TVM -> ARM wall clock).

Two oracles, both producing roofline-term latencies for TPU v5e:

* ``policy_latency`` — fast analytic per-unit model (closed-form roofline:
  compute / memory / collective terms with MXU 128-padding, int8 = 2x MXU,
  int4 weight packing, KV-cache traffic, MoE active-expert traffic). This is
  what the RL reward probes every episode — the paper's "measure on device",
  executable thousands of times without a compile.

* ``roofline_from_compiled`` — derive the same three terms from an actual
  ``jit(...).lower().compile()`` artifact: FLOPs/bytes from
  ``cost_analysis()``, collective bytes parsed from the (GSPMD-partitioned)
  HLO. Used by the dry-run, the §Roofline table, and to calibrate the
  analytic oracle.

TPU truth table encoded here (DESIGN.md §1): "FP32" policy mode runs as
native bf16; INT8 doubles MXU throughput and halves weight/act traffic;
MIX <= 4-bit weights halve traffic again (int4 packing) but do NOT add
compute speed; MIX 5-6 bit weights ride in int8 containers (no memory win
over INT8 — the oracle makes the agent discover this, like the paper's
">6 bits is slower than INT8 on ARM" finding).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core.policy import Policy, PolicyBatch, stack_policies
from repro.core.spec import LayerCMP, LayerSpec, effective_bits


@dataclass(frozen=True)
class HardwareTarget:
    name: str = "tpu-v5e"
    peak_bf16: float = 197e12          # FLOP/s per chip
    peak_int8: float = 394e12          # OP/s per chip
    hbm_bw: float = 819e9              # B/s per chip
    ici_bw: float = 50e9               # B/s per link
    mxu_align: int = 128
    op_overhead: float = 1e-7          # per fused-op dispatch (XLA fuses
                                       # whole blocks; ~0.1us residual)


V5E = HardwareTarget()


@dataclass(frozen=True)
class LatencyContext:
    tokens: int                        # tokens processed by one step
    seq_ctx: int = 0                   # attention context length
    mode: str = "prefill"              # train|prefill|decode
    chips: int = 1
    tp: int = 1                        # model-axis ways (activation collectives)
    cache_bits: int = 16               # KV-cache storage precision
    batch: int = 1


def _weight_bytes_per_elem(w_bits: int) -> float:
    if w_bits >= 9:
        return 2.0                     # native bf16
    if w_bits >= 5:
        return 1.0                     # int8 container
    return 0.5                         # int4 packing


def _act_bytes_per_elem(a_bits: int) -> float:
    return 1.0 if a_bits <= 8 else 2.0


# Weight-container buckets, in the fixed order calibration tables use:
# column 0 = raw (bf16/f32), 1 = int8 container, 2 = packed int4.
CONTAINERS = ("raw", "int8", "int4")


def container_for_bits(w_bits: int) -> str:
    """Deployment container a ``w_bits``-wide weight ships in — the same
    thresholds as ``_weight_bytes_per_elem`` (>=9 raw, 5..8 int8, <=4
    packed int4). Calibration tables (core/measure.py) are keyed by
    (layer kind, container)."""
    if w_bits >= 9:
        return "raw"
    return "int8" if w_bits >= 5 else "int4"


def pad_align(x, align, xp=np):
    """MXU-lane padding: ceil(max(x, 1) / align) * align. One definition
    for all three oracle forms — scalars and numpy arrays with the
    default ``xp=np``, traced arrays with ``xp=jnp``."""
    return xp.ceil(xp.maximum(x, 1.0) / align) * align


def _pad(x: float, align: int) -> float:
    return float(pad_align(x, align))


def _peak(w_bits: int, a_bits: int, hw: HardwareTarget) -> float:
    return hw.peak_int8 if (w_bits <= 8 and a_bits <= 8) else hw.peak_bf16


@dataclass
class UnitLatency:
    name: str
    compute_s: float
    memory_s: float
    collective_s: float = 0.0

    @property
    def time_s(self) -> float:
        # compute/memory overlap within a fused op; collectives exposed
        return max(self.compute_s, self.memory_s) + self.collective_s


@dataclass
class PolicyLatency:
    units: list = field(default_factory=list)
    overhead_s: float = 0.0

    @property
    def total_s(self) -> float:
        return sum(u.time_s for u in self.units) + self.overhead_s

    @property
    def compute_s(self) -> float:
        return sum(u.compute_s for u in self.units)

    @property
    def memory_s(self) -> float:
        return sum(u.memory_s for u in self.units)

    @property
    def collective_s(self) -> float:
        return sum(u.collective_s for u in self.units)

    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def _resolve_keep_fracs(specs: Sequence[LayerSpec], policy: Policy) -> dict:
    """dep_group name -> keep fraction provided by the owning unit."""
    fracs: dict[str, float] = {}
    for s, c in zip(specs, policy.cmps):
        if not s.prunable or not s.prune_dim:
            continue
        frac = c.keep / s.prune_dim
        if s.kind == "attn_qkv":
            fracs[f"L{s.layer_idx}.heads"] = frac
        elif s.kind == "mlp_up":
            grp = "dense_ff" if s.extra.get("dense_residual") else "ff"
            fracs[f"L{s.layer_idx}.{grp}"] = frac
        elif s.kind == "moe_up":
            fracs[f"L{s.layer_idx}.moe_ff"] = frac
        elif s.kind == "ssm_in":
            fracs[f"L{s.layer_idx}.ssm_heads"] = frac
        elif s.kind == "rglru_in":
            fracs[f"L{s.layer_idx}.lru"] = frac
    return fracs


def unit_latency(spec: LayerSpec, cmp: LayerCMP, in_frac: float,
                 hw: HardwareTarget, ctx: LatencyContext) -> UnitLatency:
    w_bits, a_bits = effective_bits(cmp)
    keep_frac = (cmp.keep / spec.prune_dim) if spec.prune_dim else 1.0
    T = ctx.tokens
    chips = max(1, ctx.chips)

    # --- matmul dims after pruning + MXU padding ---
    if spec.kind == "conv":
        # im2col on the MXU: m = spatial positions, k = k²·cin, n = cout.
        # Channels pad to the 128 lane width — pruning below a 128
        # boundary buys no MXU time (TPU truth; ARM had no such floor).
        px = spec.extra.get("px", 1)
        m = T * px
        k_dim = (spec.weight_elems / max(1, spec.out_dim)) * in_frac
        n_dim = spec.out_dim * keep_frac
        k_pad = _pad(k_dim, hw.mxu_align)
        n_pad = _pad(n_dim, hw.mxu_align)
        flops = 2.0 * m * k_pad * n_pad
        w_bytes = (spec.weight_elems * in_frac * keep_frac
                   * _weight_bytes_per_elem(w_bits))
        a_bytes = m * k_dim * _act_bytes_per_elem(a_bits) + m * n_dim * 2.0
        compute = flops / (_peak(w_bits, a_bits, hw) * chips)
        memory = (w_bytes + a_bytes) / (hw.hbm_bw * chips)
        return UnitLatency(spec.name, compute, memory)
    k_dim = spec.in_dim * in_frac
    if spec.kind == "attn_qkv":
        hd = spec.extra.get("head_dim", 128)
        kv = spec.extra.get("kv_heads", 0)
        n_dim = keep_frac * (spec.out_dim - 2 * kv * hd) + 2 * kv * hd
    elif spec.prunable and spec.prune_dim:
        n_dim = spec.out_dim * keep_frac
    else:
        n_dim = spec.out_dim
    k_pad = _pad(k_dim, hw.mxu_align)
    n_pad = _pad(n_dim, hw.mxu_align)

    if spec.kind == "embed":
        # gather: one row per token
        mem = T * spec.out_dim * _weight_bytes_per_elem(w_bits)
        return UnitLatency(spec.name, 0.0, mem / (hw.hbm_bw * chips))

    # number of matmuls fused in this unit (e.g. gated MLP up+gate = 2)
    E_cnt = spec.extra.get("experts", 1) or 1
    n_mats = max(1.0, spec.weight_elems /
                 max(1, spec.in_dim * spec.out_dim * E_cnt))
    flops = 2.0 * T * k_pad * n_pad * n_mats
    expert_frac = 1.0
    if spec.kind in ("moe_up", "moe_down"):
        K = spec.extra["top_k"]
        flops = 2.0 * T * K * k_pad * n_pad * n_mats
        # weights touched: small batches only stream active experts' rows
        expert_frac = min(1.0, (ctx.batch * K) / E_cnt) \
            if ctx.mode == "decode" else 1.0

    w_elems = spec.weight_elems * keep_frac * in_frac * expert_frac
    w_bytes = w_elems * _weight_bytes_per_elem(w_bits)
    a_bytes = T * k_dim * _act_bytes_per_elem(a_bits) + T * n_dim * 2.0

    compute = flops / (_peak(w_bits, a_bits, hw) * chips)
    memory = (w_bytes + a_bytes) / (hw.hbm_bw * chips)

    # TP activation collective (all-reduce of the unit output) when sharded
    coll = 0.0
    if ctx.tp > 1 and spec.kind in ("attn_out", "mlp_down", "moe_down",
                                    "ssm_out", "rglru_out", "head"):
        coll = 2.0 * T * n_dim * 2.0 * (ctx.tp - 1) / ctx.tp / hw.ici_bw
    return UnitLatency(spec.name, compute, memory, coll)


def _attention_extra(spec: LayerSpec, cmp: LayerCMP, hw: HardwareTarget,
                     ctx: LatencyContext, window: int) -> UnitLatency:
    """Score+AV compute and KV-cache traffic for one attention layer."""
    hd = spec.extra.get("head_dim", 128)
    kv = spec.extra.get("kv_heads", 1)
    keep_heads = cmp.keep if spec.prune_dim else 0
    S = ctx.seq_ctx if window <= 0 else min(ctx.seq_ctx, window)
    chips = max(1, ctx.chips)
    flops = 4.0 * ctx.tokens * S * hd * keep_heads
    if ctx.mode in ("train", "prefill"):
        flops *= 0.5  # causal: half the positions on average
    cache_bytes = ctx.tokens * S * 2 * kv * hd * (ctx.cache_bits / 8.0)
    comp = flops / (hw.peak_bf16 * chips)
    mem = cache_bytes / (hw.hbm_bw * chips)
    return UnitLatency(spec.name + ".attn", comp, mem)


def _scale_unit(u: UnitLatency, f: float) -> UnitLatency:
    return UnitLatency(u.name, u.compute_s * f, u.memory_s * f,
                       u.collective_s * f)


def policy_latency(specs: Sequence[LayerSpec], policy: Policy,
                   hw: HardwareTarget = V5E,
                   ctx: Optional[LatencyContext] = None,
                   window: int = 0, calib=None) -> PolicyLatency:
    """``calib``: optional measured-vs-analytic correction table
    (core/measure.py ``CalibrationTable``); unit terms are scaled by the
    fitted (kind, container) factor, attention extras and dispatch
    overhead by the lumped residual factors."""
    ctx = ctx or LatencyContext(tokens=1, seq_ctx=1, mode="decode")
    fracs = _resolve_keep_fracs(specs, policy)
    out = PolicyLatency()
    n_ops = 0
    for s, c in zip(specs, policy.cmps):
        in_frac = fracs.get(s.dep_group, 1.0) if s.dep_group else 1.0
        u = unit_latency(s, c, in_frac, hw, ctx)
        if calib is not None:
            w_bits, _ = effective_bits(c)
            u = _scale_unit(u, calib.factor(s.kind, container_for_bits(w_bits)))
        out.units.append(u)
        n_ops += 1
        if s.kind == "attn_qkv" and ctx.seq_ctx > 0:
            e = _attention_extra(s, c, hw, ctx, window)
            if calib is not None:
                e = _scale_unit(e, calib.extra_factor())
            out.units.append(e)
            n_ops += 1
    out.overhead_s = n_ops * hw.op_overhead \
        * (calib.overhead_factor() if calib is not None else 1.0)
    return out


# ===========================================================================
# Vectorized analytic oracle — K policies as one stack of array ops
# ===========================================================================

_COLL_KINDS = ("attn_out", "mlp_down", "moe_down", "ssm_out", "rglru_out",
               "head")


@dataclass
class BatchedPolicyLatency:
    """Latency of K policies at once; mirrors ``PolicyLatency`` totals.

    ``unit_time_s`` is (K, L) in spec order; ``extra_time_s`` is (K, E)
    for the attention score/AV+KV-cache terms, with ``extra_spec_idx``
    mapping each extra column back to its attn_qkv spec.
    """
    unit_time_s: np.ndarray
    extra_time_s: np.ndarray
    extra_spec_idx: np.ndarray
    overhead_s: float

    @property
    def total_s(self) -> np.ndarray:
        return (self.unit_time_s.sum(axis=1)
                + self.extra_time_s.sum(axis=1) + self.overhead_s)

    def decided_before(self, t: int) -> np.ndarray:
        """Per-policy latency of units with spec index < t (the AMC
        'reduced' bookkeeping feature, under the partial policy)."""
        out = self.unit_time_s[:, :t].sum(axis=1)
        if self.extra_time_s.shape[1]:
            cols = self.extra_spec_idx < t
            out = out + self.extra_time_s[:, cols].sum(axis=1)
        return out


class BatchOracle:
    """Precomputed per-spec tables; calling it evaluates a PolicyBatch
    with numpy array ops instead of the per-layer Python loop."""

    def __init__(self, specs: Sequence[LayerSpec], hw: HardwareTarget,
                 ctx: LatencyContext, window: int = 0, calib=None):
        self.specs, self.hw, self.ctx, self.window = specs, hw, ctx, window
        self.calib = calib
        if calib is not None:
            self.calib_f = np.asarray(calib.unit_factors(specs), np.float64)
            self.extra_f = float(calib.extra_factor())
            self.overhead_f = float(calib.overhead_factor())
        else:
            self.calib_f, self.extra_f, self.overhead_f = None, 1.0, 1.0
        L = len(specs)
        g = lambda f: np.asarray([f(s) for s in specs], np.float64)
        self.is_conv = np.asarray([s.kind == "conv" for s in specs])
        self.is_embed = np.asarray([s.kind == "embed" for s in specs])
        self.is_qkv = np.asarray([s.kind == "attn_qkv" for s in specs])
        self.is_moe = np.asarray([s.kind in ("moe_up", "moe_down")
                                  for s in specs])
        is_coll = np.asarray([s.kind in _COLL_KINDS for s in specs])
        self.prunable = np.asarray([bool(s.prunable and s.prune_dim)
                                    for s in specs])
        self.in_dim = g(lambda s: s.in_dim)
        self.out_dim = g(lambda s: s.out_dim)
        self.prune_dim = g(lambda s: s.prune_dim)
        self.weight_elems = g(lambda s: s.weight_elems)
        self.px = g(lambda s: s.extra.get("px", 1))
        self.hd = g(lambda s: s.extra.get("head_dim", 128))
        self.kv = g(lambda s: s.extra.get("kv_heads", 0))
        self.kv_cache = g(lambda s: s.extra.get("kv_heads", 1))
        e_cnt = g(lambda s: s.extra.get("experts", 1) or 1)
        self.n_mats = np.maximum(
            1.0, self.weight_elems /
            np.maximum(1.0, self.in_dim * self.out_dim * e_cnt))
        self.top_k = g(lambda s: s.extra.get("top_k", 1) or 1)
        if ctx.mode == "decode":
            self.expert_frac = np.where(
                self.is_moe,
                np.minimum(1.0, (ctx.batch * self.top_k) / e_cnt), 1.0)
        else:
            self.expert_frac = np.ones(L)
        # dep_group -> owning unit index (same mapping as
        # _resolve_keep_fracs, but positional)
        groups: dict[str, int] = {}
        for i, s in enumerate(specs):
            if not s.prunable or not s.prune_dim:
                continue
            if s.kind == "attn_qkv":
                groups[f"L{s.layer_idx}.heads"] = i
            elif s.kind == "mlp_up":
                grp = "dense_ff" if s.extra.get("dense_residual") else "ff"
                groups[f"L{s.layer_idx}.{grp}"] = i
            elif s.kind == "moe_up":
                groups[f"L{s.layer_idx}.moe_ff"] = i
            elif s.kind == "ssm_in":
                groups[f"L{s.layer_idx}.ssm_heads"] = i
            elif s.kind == "rglru_in":
                groups[f"L{s.layer_idx}.lru"] = i
        self.owner = np.asarray(
            [groups.get(s.dep_group, -1) if s.dep_group else -1
             for s in specs])
        T, tp = ctx.tokens, ctx.tp
        self.coll_coef = np.where(
            is_coll & (tp > 1),
            2.0 * T * 2.0 * (tp - 1) / max(1, tp) / hw.ici_bw, 0.0)
        # attention score/AV + KV-cache extras (one column per attn_qkv)
        self.extra_idx = np.nonzero(self.is_qkv)[0] if ctx.seq_ctx > 0 \
            else np.zeros((0,), np.int64)
        self.n_ops = L + len(self.extra_idx)

    def _pad(self, x: np.ndarray) -> np.ndarray:
        return pad_align(x, self.hw.mxu_align)

    def __call__(self, batch: PolicyBatch) -> BatchedPolicyLatency:
        hw, ctx = self.hw, self.ctx
        T, chips = ctx.tokens, max(1, ctx.chips)
        keep, wb, ab = batch.keep, batch.w_bits, batch.a_bits

        keep_frac = np.where(self.prune_dim > 0,
                             keep / np.maximum(self.prune_dim, 1.0), 1.0)
        in_frac = np.where(self.owner >= 0,
                           keep_frac[:, np.maximum(self.owner, 0)], 1.0)
        wbpe = np.where(wb >= 9, 2.0, np.where(wb >= 5, 1.0, 0.5))
        abpe = np.where(ab <= 8, 1.0, 2.0)
        peak = np.where((wb <= 8) & (ab <= 8), hw.peak_int8, hw.peak_bf16)

        k_dim = np.where(
            self.is_conv,
            (self.weight_elems / np.maximum(1.0, self.out_dim)) * in_frac,
            self.in_dim * in_frac)
        n_dim = np.where(
            self.is_qkv,
            keep_frac * (self.out_dim - 2 * self.kv * self.hd)
            + 2 * self.kv * self.hd,
            np.where(self.prunable, self.out_dim * keep_frac, self.out_dim))
        k_pad, n_pad = self._pad(k_dim), self._pad(n_dim)

        m_rows = np.where(self.is_conv, T * self.px, T)
        flops = 2.0 * m_rows * k_pad * n_pad * np.where(
            self.is_conv, 1.0,
            self.n_mats * np.where(self.is_moe, self.top_k, 1.0))
        w_bytes = (self.weight_elems * keep_frac * in_frac
                   * self.expert_frac * wbpe)
        a_bytes = m_rows * k_dim * abpe + m_rows * n_dim * 2.0

        compute = flops / (peak * chips)
        memory = (w_bytes + a_bytes) / (hw.hbm_bw * chips)
        compute = np.where(self.is_embed, 0.0, compute)
        memory = np.where(self.is_embed,
                          T * self.out_dim * wbpe / (hw.hbm_bw * chips),
                          memory)
        coll = self.coll_coef * n_dim
        unit_time = np.maximum(compute, memory) + coll
        if self.calib_f is not None:
            bucket = np.where(wb >= 9, 0, np.where(wb >= 5, 1, 2))
            unit_time = unit_time * self.calib_f[
                np.arange(len(self.specs))[None, :], bucket.astype(np.int64)]

        if len(self.extra_idx):
            q = self.extra_idx
            S = ctx.seq_ctx if self.window <= 0 \
                else min(ctx.seq_ctx, self.window)
            keep_heads = np.where(self.prune_dim[q] > 0, keep[:, q], 0.0)
            eflops = 4.0 * T * S * self.hd[q] * keep_heads
            if ctx.mode in ("train", "prefill"):
                eflops = eflops * 0.5
            cache = T * S * 2 * self.kv_cache[q] * self.hd[q] \
                * (ctx.cache_bits / 8.0)
            extra = np.maximum(eflops / (hw.peak_bf16 * chips),
                               cache / (hw.hbm_bw * chips))
        else:
            extra = np.zeros((len(batch), 0))
        return BatchedPolicyLatency(
            unit_time_s=unit_time, extra_time_s=extra * self.extra_f,
            extra_spec_idx=self.extra_idx,
            overhead_s=self.n_ops * hw.op_overhead * self.overhead_f)


def fifo_cached(cache: dict, max_entries: int, key, is_valid, factory):
    """Identity-guarded FIFO cache lookup, shared by the oracle and
    static-feature caches.

    Entries are value-keyed (``key`` may embed ``id()``s of
    identity-keyed operands); ``is_valid(hit)`` re-probes those
    identities so a recycled id can never serve a stale entry (the
    cached value holds strong refs, keeping live ids stable). On
    insert, the OLDEST entries are evicted (dict = insertion order) —
    a long multi-member search only recomputes one member's tables,
    never everyone's at once.
    """
    hit = cache.get(key)
    if hit is not None and is_valid(hit):
        return hit
    # drop a stale entry for this key first: the rebuild replaces it
    # (no growth, so nobody else gets evicted) and the fresh entry
    # takes a NEW insertion position instead of inheriting the old one
    cache.pop(key, None)
    while len(cache) >= max_entries:
        del cache[next(iter(cache))]
    hit = factory()
    cache[key] = hit
    return hit


_oracle_cache: dict = {}
_ORACLE_CACHE_MAX = 64


def get_batch_oracle(specs: Sequence[LayerSpec], hw: HardwareTarget,
                     ctx: LatencyContext, window: int = 0,
                     calib=None) -> BatchOracle:
    # ctx/hw are frozen dataclasses, so value-keying is safe; specs and
    # calib tables are identity-keyed with the fifo_cached identity guard
    return fifo_cached(
        _oracle_cache, _ORACLE_CACHE_MAX,
        (id(specs), hw, ctx, window, id(calib) if calib is not None else None),
        lambda o: o.specs is specs and o.calib is calib,
        lambda: BatchOracle(specs, hw, ctx, window, calib))


def policy_latency_batch(
        specs: Sequence[LayerSpec],
        policies: Union[PolicyBatch, Sequence[Policy]],
        hw: HardwareTarget = V5E, ctx: Optional[LatencyContext] = None,
        window: int = 0, calib=None) -> BatchedPolicyLatency:
    """Vectorized ``policy_latency`` over a stack of K policies.

    Matches the scalar oracle term-for-term (same roofline formulas in
    float64) so ``out.total_s[k] == policy_latency(specs, policies[k],
    ...).total_s`` up to summation order.
    """
    ctx = ctx or LatencyContext(tokens=1, seq_ctx=1, mode="decode")
    if not isinstance(policies, PolicyBatch):
        policies = stack_policies(specs, policies)
    return get_batch_oracle(specs, hw, ctx, window, calib)(policies)


# ===========================================================================
# Traceable analytic oracle — the BatchOracle in jnp, for in-scan rollouts
# ===========================================================================

class HwParams(NamedTuple):
    """The hardware scalars the roofline actually divides by, as a
    vmappable pytree: stack P of them and ``vmap`` the oracle to
    evaluate one policy batch per hardware target in a single dispatch.
    ``mxu_align`` stays static on the oracle (it shapes the padding
    formula, and every supported TPU generation uses 128)."""
    peak_bf16: jnp.ndarray
    peak_int8: jnp.ndarray
    hbm_bw: jnp.ndarray
    ici_bw: jnp.ndarray
    op_overhead: jnp.ndarray


def hw_params(hw: HardwareTarget) -> HwParams:
    return HwParams(
        peak_bf16=jnp.asarray(hw.peak_bf16, jnp.float32),
        peak_int8=jnp.asarray(hw.peak_int8, jnp.float32),
        hbm_bw=jnp.asarray(hw.hbm_bw, jnp.float32),
        ici_bw=jnp.asarray(hw.ici_bw, jnp.float32),
        op_overhead=jnp.asarray(hw.op_overhead, jnp.float32))


class JaxBatchOracle:
    """``BatchOracle``'s roofline as pure jnp — the oracle the fused
    rollout scan probes every layer step without leaving the device.

    Tables are borrowed from the (cached) numpy oracle and baked into
    the trace as f32 constants; everything hardware-rate-dependent is
    deferred to an ``HwParams`` argument so one traced oracle serves a
    vmapped stack of hardware targets. Matches the numpy oracle
    term-for-term up to f32 rounding (the parity property tests bound
    the drift at 1e-5 on the downstream features).
    """

    def __init__(self, specs: Sequence[LayerSpec], hw: HardwareTarget,
                 ctx: LatencyContext, window: int = 0, calib=None):
        b = get_batch_oracle(specs, hw, ctx, window, calib)
        f32 = lambda x: jnp.asarray(x, jnp.float32)
        self.specs, self.hw, self.ctx, self.window = specs, hw, ctx, window
        # calibration factors bake into the trace as constants: the fused
        # rollout stays at its single-dispatch bound in calibrated mode
        self.calib = calib
        self.calib_f = None if b.calib_f is None else f32(b.calib_f)
        self.extra_f = float(b.extra_f)
        self.overhead_f = float(b.overhead_f)
        self.hwp = hw_params(hw)
        self.is_conv = jnp.asarray(b.is_conv)
        self.is_embed = jnp.asarray(b.is_embed)
        self.is_qkv = jnp.asarray(b.is_qkv)
        self.is_moe = jnp.asarray(b.is_moe)
        self.prunable = jnp.asarray(b.prunable)
        self.in_dim = f32(b.in_dim)
        self.out_dim = f32(b.out_dim)
        self.prune_dim = f32(b.prune_dim)
        self.weight_elems = f32(b.weight_elems)
        self.px = f32(b.px)
        self.hd = f32(b.hd)
        self.kv = f32(b.kv)
        self.n_mats = f32(b.n_mats)
        self.top_k = f32(b.top_k)
        self.expert_frac = f32(b.expert_frac)
        self.owner = jnp.asarray(np.maximum(b.owner, 0))
        self.has_owner = jnp.asarray(b.owner >= 0)
        # BatchOracle folds 1/ici_bw into coll_coef; keep the rate out so
        # HwParams can swap it per target
        self.coll_base = f32(b.coll_coef * hw.ici_bw)
        self.extra_idx = jnp.asarray(b.extra_idx)
        self.spec_idx = jnp.arange(len(specs))
        self.n_ops = b.n_ops
        self.mxu_align = float(hw.mxu_align)
        self.chips = float(max(1, ctx.chips))
        self.tokens = float(ctx.tokens)
        self.causal = ctx.mode in ("train", "prefill")
        self.seq = float(ctx.seq_ctx if window <= 0
                         else min(ctx.seq_ctx, window))
        if len(b.extra_idx):
            q = b.extra_idx
            self.extra_hd = f32(b.hd[q])
            self.extra_prunable = jnp.asarray(b.prune_dim[q] > 0)
            self.extra_cache_bytes = f32(
                ctx.tokens * self.seq * 2 * b.kv_cache[q] * b.hd[q]
                * (ctx.cache_bits / 8.0))

    def _pad(self, x):
        return pad_align(x, self.mxu_align, xp=jnp)

    def unit_times(self, keep, wb, ab, hwp: Optional[HwParams] = None):
        """(K, L) per-unit and (K, E) attention-extra times — the same
        terms as ``BatchOracle.__call__``, traceable."""
        hwp = self.hwp if hwp is None else hwp
        T, chips = self.tokens, self.chips
        keep = jnp.asarray(keep, jnp.float32)
        wb = jnp.asarray(wb, jnp.float32)
        ab = jnp.asarray(ab, jnp.float32)

        keep_frac = jnp.where(self.prune_dim > 0,
                              keep / jnp.maximum(self.prune_dim, 1.0), 1.0)
        in_frac = jnp.where(self.has_owner, keep_frac[:, self.owner], 1.0)
        wbpe = jnp.where(wb >= 9, 2.0, jnp.where(wb >= 5, 1.0, 0.5))
        abpe = jnp.where(ab <= 8, 1.0, 2.0)
        peak = jnp.where((wb <= 8) & (ab <= 8), hwp.peak_int8,
                         hwp.peak_bf16)

        k_dim = jnp.where(
            self.is_conv,
            (self.weight_elems / jnp.maximum(1.0, self.out_dim)) * in_frac,
            self.in_dim * in_frac)
        n_dim = jnp.where(
            self.is_qkv,
            keep_frac * (self.out_dim - 2 * self.kv * self.hd)
            + 2 * self.kv * self.hd,
            jnp.where(self.prunable, self.out_dim * keep_frac,
                      self.out_dim))
        k_pad, n_pad = self._pad(k_dim), self._pad(n_dim)

        m_rows = jnp.where(self.is_conv, T * self.px, T)
        flops = 2.0 * m_rows * k_pad * n_pad * jnp.where(
            self.is_conv, 1.0,
            self.n_mats * jnp.where(self.is_moe, self.top_k, 1.0))
        w_bytes = (self.weight_elems * keep_frac * in_frac
                   * self.expert_frac * wbpe)
        a_bytes = m_rows * k_dim * abpe + m_rows * n_dim * 2.0

        compute = flops / (peak * chips)
        memory = (w_bytes + a_bytes) / (hwp.hbm_bw * chips)
        compute = jnp.where(self.is_embed, 0.0, compute)
        memory = jnp.where(self.is_embed,
                           T * self.out_dim * wbpe / (hwp.hbm_bw * chips),
                           memory)
        coll = self.coll_base / hwp.ici_bw * n_dim
        unit_time = jnp.maximum(compute, memory) + coll
        if self.calib_f is not None:
            bucket = jnp.where(wb >= 9, 0, jnp.where(wb >= 5, 1, 2))
            unit_time = unit_time * self.calib_f[
                self.spec_idx[None, :], bucket.astype(jnp.int32)]

        if len(self.extra_idx):
            keep_heads = jnp.where(self.extra_prunable,
                                   keep[:, self.extra_idx], 0.0)
            eflops = 4.0 * T * self.seq * self.extra_hd * keep_heads
            if self.causal:
                eflops = eflops * 0.5
            extra = jnp.maximum(
                eflops / (hwp.peak_bf16 * chips),
                self.extra_cache_bytes / (hwp.hbm_bw * chips)) * self.extra_f
        else:
            extra = jnp.zeros((keep.shape[0], 0), jnp.float32)
        return unit_time, extra

    def totals(self, unit_time, extra_time,
               hwp: Optional[HwParams] = None):
        hwp = self.hwp if hwp is None else hwp
        return (unit_time.sum(axis=1) + extra_time.sum(axis=1)
                + self.n_ops * hwp.op_overhead * self.overhead_f)

    def decided_before(self, unit_time, extra_time, t):
        """Per-policy latency of units with spec index < t (traced t) —
        the in-scan form of ``BatchedPolicyLatency.decided_before``."""
        out = (unit_time * (self.spec_idx < t)).sum(axis=1)
        if len(self.extra_idx):
            out = out + (extra_time * (self.extra_idx < t)).sum(axis=1)
        return out


_jax_oracle_cache: dict = {}


def get_jax_oracle(specs: Sequence[LayerSpec], hw: HardwareTarget,
                   ctx: LatencyContext, window: int = 0,
                   calib=None) -> JaxBatchOracle:
    """FIFO-evicting cache, same keying rules as ``get_batch_oracle``."""
    return fifo_cached(
        _jax_oracle_cache, _ORACLE_CACHE_MAX,
        (id(specs), hw, ctx, window, id(calib) if calib is not None else None),
        lambda o: o.specs is specs and o.calib is calib,
        lambda: JaxBatchOracle(specs, hw, ctx, window, calib))


# ===========================================================================
# Compiled-HLO oracle (dry-run / §Roofline)
# ===========================================================================

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}


def _first_shape_bytes(line: str) -> float:
    """Bytes of the result shape(s) on an HLO instruction line (handles
    tuple results, e.g. reduce-scatter -> (f32[32], f32[32]))."""
    lhs = line.split(" = ", 1)
    target = lhs[1] if len(lhs) == 2 else line
    total = 0.0
    m = _COLLECTIVE_RE.search(target)
    head = target[:m.start()] if m else target.split("(", 1)[0]
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind over an HLO module."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or " = " not in line:
            continue
        if "-done" in line:  # avoid double counting async pairs
            continue
        kind = m.group(1)
        b = _first_shape_bytes(line)
        out[kind] = out.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    out["_counts"] = count
    return out


@dataclass
class RooflineReport:
    """Roofline terms from a compiled SPMD artifact.

    IMPORTANT semantics: ``cost_analysis()`` on a GSPMD-partitioned module
    reports PER-DEVICE flops/bytes (each device executes the partitioned
    program), and HLO shapes in the partitioned module are per-shard — so
    ``flops``/``bytes_accessed``/``collective_bytes`` here are per-chip.
    The spec formula  compute = HLO_FLOPs / (chips × peak)  is recovered
    because global HLO_FLOPs = per-chip × chips.  ``model_flops`` is GLOBAL
    (6·N·D over the full batch).
    """
    flops: float                       # per-chip
    bytes_accessed: float              # per-chip
    collective_bytes: float            # per-chip
    per_collective: dict
    chips: int
    hw: HardwareTarget
    model_flops: float = 0.0           # 6·N·D-style useful flops (global)
    compute_dtype: str = "bf16"        # dominant dot/conv operand dtype

    @property
    def compute_peak(self) -> float:
        """Per-chip peak for the program's dominant matmul dtype — an
        int8-quantized program runs the MXU at ``peak_int8``, not
        ``peak_bf16`` (a 2x-pessimistic compute term would bias the
        measured-latency calibration)."""
        return self.hw.peak_int8 if self.compute_dtype == "int8" \
            else self.hw.peak_bf16

    @property
    def compute_s(self) -> float:
        return self.flops / self.compute_peak

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """GLOBAL useful flops / GLOBAL compiled flops (flops field is
        per-chip)."""
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modelled step
        time (useful-FLOPs MFU bound)."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops / self.step_s) / (self.hw.peak_bf16 *
                                                   self.chips)

    def summary(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_s, "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "compute_dtype": self.compute_dtype,
        }


_DOT_RE = re.compile(r"\b(?:dot|convolution)\(")
_INT_MXU_DTYPES = frozenset(("s8", "u8", "s4", "u4"))


def hlo_compute_dtype(hlo_text: str) -> str:
    """Dominant MXU dtype of an HLO module: ``"int8"`` when any
    dot/convolution line carries integer (s8/u8/s4/u4) operand or result
    shapes, ``"bf16"`` otherwise. Operand shapes are not always printed
    on the instruction line (post-optimization HLO may reference bare
    ``%operand`` names), so integer shapes anywhere on a dot/conv line —
    including the convert fusions XLA folds into them — count."""
    for line in hlo_text.splitlines():
        if not _DOT_RE.search(line):
            continue
        for dt, _ in _SHAPE_RE.findall(line):
            if dt in _INT_MXU_DTYPES:
                return "int8"
    return "bf16"


def roofline_from_compiled(compiled, hlo_text: Optional[str] = None,
                           chips: int = 1, hw: HardwareTarget = V5E,
                           model_flops: float = 0.0,
                           compute_dtype: Optional[str] = None
                           ) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = hlo_collective_bytes(text)
    cbytes = sum(v for k, v in colls.items() if not k.startswith("_"))
    if compute_dtype is None:
        compute_dtype = hlo_compute_dtype(text)
    return RooflineReport(flops=flops, bytes_accessed=byts,
                          collective_bytes=cbytes, per_collective=colls,
                          chips=chips, hw=hw, model_flops=model_flops,
                          compute_dtype=compute_dtype)
