"""Hardware latency oracle — the TPU stand-in for the paper's
compile-and-measure loop (TVM -> ARM wall clock).

Two oracles, both producing roofline-term latencies for TPU v5e:

* ``policy_latency`` — fast analytic per-unit model (closed-form roofline:
  compute / memory / collective terms with MXU 128-padding, int8 = 2x MXU,
  int4 weight packing, KV-cache traffic, MoE active-expert traffic). This is
  what the RL reward probes every episode — the paper's "measure on device",
  executable thousands of times without a compile.

* ``roofline_from_compiled`` — derive the same three terms from an actual
  ``jit(...).lower().compile()`` artifact: FLOPs/bytes from
  ``cost_analysis()``, collective bytes parsed from the (GSPMD-partitioned)
  HLO. Used by the dry-run, the §Roofline table, and to calibrate the
  analytic oracle.

TPU truth table encoded here (DESIGN.md §1): "FP32" policy mode runs as
native bf16; INT8 doubles MXU throughput and halves weight/act traffic;
MIX <= 4-bit weights halve traffic again (int4 packing) but do NOT add
compute speed; MIX 5-6 bit weights ride in int8 containers (no memory win
over INT8 — the oracle makes the agent discover this, like the paper's
">6 bits is slower than INT8 on ARM" finding).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.policy import Policy
from repro.core.spec import LayerCMP, LayerSpec, effective_bits


@dataclass(frozen=True)
class HardwareTarget:
    name: str = "tpu-v5e"
    peak_bf16: float = 197e12          # FLOP/s per chip
    peak_int8: float = 394e12          # OP/s per chip
    hbm_bw: float = 819e9              # B/s per chip
    ici_bw: float = 50e9               # B/s per link
    mxu_align: int = 128
    op_overhead: float = 1e-7          # per fused-op dispatch (XLA fuses
                                       # whole blocks; ~0.1us residual)


V5E = HardwareTarget()


@dataclass
class LatencyContext:
    tokens: int                        # tokens processed by one step
    seq_ctx: int = 0                   # attention context length
    mode: str = "prefill"              # train|prefill|decode
    chips: int = 1
    tp: int = 1                        # model-axis ways (activation collectives)
    cache_bits: int = 16               # KV-cache storage precision
    batch: int = 1


def _weight_bytes_per_elem(w_bits: int) -> float:
    if w_bits >= 9:
        return 2.0                     # native bf16
    if w_bits >= 5:
        return 1.0                     # int8 container
    return 0.5                         # int4 packing


def _act_bytes_per_elem(a_bits: int) -> float:
    return 1.0 if a_bits <= 8 else 2.0


def _pad(x: float, align: int) -> float:
    return math.ceil(max(x, 1) / align) * align


def _peak(w_bits: int, a_bits: int, hw: HardwareTarget) -> float:
    return hw.peak_int8 if (w_bits <= 8 and a_bits <= 8) else hw.peak_bf16


@dataclass
class UnitLatency:
    name: str
    compute_s: float
    memory_s: float
    collective_s: float = 0.0

    @property
    def time_s(self) -> float:
        # compute/memory overlap within a fused op; collectives exposed
        return max(self.compute_s, self.memory_s) + self.collective_s


@dataclass
class PolicyLatency:
    units: list = field(default_factory=list)
    overhead_s: float = 0.0

    @property
    def total_s(self) -> float:
        return sum(u.time_s for u in self.units) + self.overhead_s

    @property
    def compute_s(self) -> float:
        return sum(u.compute_s for u in self.units)

    @property
    def memory_s(self) -> float:
        return sum(u.memory_s for u in self.units)

    @property
    def collective_s(self) -> float:
        return sum(u.collective_s for u in self.units)

    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def _resolve_keep_fracs(specs: Sequence[LayerSpec], policy: Policy) -> dict:
    """dep_group name -> keep fraction provided by the owning unit."""
    fracs: dict[str, float] = {}
    for s, c in zip(specs, policy.cmps):
        if not s.prunable or not s.prune_dim:
            continue
        frac = c.keep / s.prune_dim
        if s.kind == "attn_qkv":
            fracs[f"L{s.layer_idx}.heads"] = frac
        elif s.kind == "mlp_up":
            grp = "dense_ff" if s.extra.get("dense_residual") else "ff"
            fracs[f"L{s.layer_idx}.{grp}"] = frac
        elif s.kind == "moe_up":
            fracs[f"L{s.layer_idx}.moe_ff"] = frac
        elif s.kind == "ssm_in":
            fracs[f"L{s.layer_idx}.ssm_heads"] = frac
        elif s.kind == "rglru_in":
            fracs[f"L{s.layer_idx}.lru"] = frac
    return fracs


def unit_latency(spec: LayerSpec, cmp: LayerCMP, in_frac: float,
                 hw: HardwareTarget, ctx: LatencyContext) -> UnitLatency:
    w_bits, a_bits = effective_bits(cmp)
    keep_frac = (cmp.keep / spec.prune_dim) if spec.prune_dim else 1.0
    T = ctx.tokens
    chips = max(1, ctx.chips)

    # --- matmul dims after pruning + MXU padding ---
    if spec.kind == "conv":
        # im2col on the MXU: m = spatial positions, k = k²·cin, n = cout.
        # Channels pad to the 128 lane width — pruning below a 128
        # boundary buys no MXU time (TPU truth; ARM had no such floor).
        px = spec.extra.get("px", 1)
        m = T * px
        k_dim = (spec.weight_elems / max(1, spec.out_dim)) * in_frac
        n_dim = spec.out_dim * keep_frac
        k_pad = _pad(k_dim, hw.mxu_align)
        n_pad = _pad(n_dim, hw.mxu_align)
        flops = 2.0 * m * k_pad * n_pad
        w_bytes = (spec.weight_elems * in_frac * keep_frac
                   * _weight_bytes_per_elem(w_bits))
        a_bytes = m * k_dim * _act_bytes_per_elem(a_bits) + m * n_dim * 2.0
        compute = flops / (_peak(w_bits, a_bits, hw) * chips)
        memory = (w_bytes + a_bytes) / (hw.hbm_bw * chips)
        return UnitLatency(spec.name, compute, memory)
    k_dim = spec.in_dim * in_frac
    if spec.kind == "attn_qkv":
        hd = spec.extra.get("head_dim", 128)
        kv = spec.extra.get("kv_heads", 0)
        n_dim = keep_frac * (spec.out_dim - 2 * kv * hd) + 2 * kv * hd
    elif spec.prunable and spec.prune_dim:
        n_dim = spec.out_dim * keep_frac
    else:
        n_dim = spec.out_dim
    k_pad = _pad(k_dim, hw.mxu_align)
    n_pad = _pad(n_dim, hw.mxu_align)

    if spec.kind == "embed":
        # gather: one row per token
        mem = T * spec.out_dim * _weight_bytes_per_elem(w_bits)
        return UnitLatency(spec.name, 0.0, mem / (hw.hbm_bw * chips))

    # number of matmuls fused in this unit (e.g. gated MLP up+gate = 2)
    E_cnt = spec.extra.get("experts", 1) or 1
    n_mats = max(1.0, spec.weight_elems /
                 max(1, spec.in_dim * spec.out_dim * E_cnt))
    flops = 2.0 * T * k_pad * n_pad * n_mats
    expert_frac = 1.0
    if spec.kind in ("moe_up", "moe_down"):
        K = spec.extra["top_k"]
        flops = 2.0 * T * K * k_pad * n_pad * n_mats
        # weights touched: small batches only stream active experts' rows
        expert_frac = min(1.0, (ctx.batch * K) / E_cnt) \
            if ctx.mode == "decode" else 1.0

    w_elems = spec.weight_elems * keep_frac * in_frac * expert_frac
    w_bytes = w_elems * _weight_bytes_per_elem(w_bits)
    a_bytes = T * k_dim * _act_bytes_per_elem(a_bits) + T * n_dim * 2.0

    compute = flops / (_peak(w_bits, a_bits, hw) * chips)
    memory = (w_bytes + a_bytes) / (hw.hbm_bw * chips)

    # TP activation collective (all-reduce of the unit output) when sharded
    coll = 0.0
    if ctx.tp > 1 and spec.kind in ("attn_out", "mlp_down", "moe_down",
                                    "ssm_out", "rglru_out", "head"):
        coll = 2.0 * T * n_dim * 2.0 * (ctx.tp - 1) / ctx.tp / hw.ici_bw
    return UnitLatency(spec.name, compute, memory, coll)


def _attention_extra(spec: LayerSpec, cmp: LayerCMP, hw: HardwareTarget,
                     ctx: LatencyContext, window: int) -> UnitLatency:
    """Score+AV compute and KV-cache traffic for one attention layer."""
    hd = spec.extra.get("head_dim", 128)
    kv = spec.extra.get("kv_heads", 1)
    keep_heads = cmp.keep if spec.prune_dim else 0
    S = ctx.seq_ctx if window <= 0 else min(ctx.seq_ctx, window)
    chips = max(1, ctx.chips)
    flops = 4.0 * ctx.tokens * S * hd * keep_heads
    if ctx.mode in ("train", "prefill"):
        flops *= 0.5  # causal: half the positions on average
    cache_bytes = ctx.tokens * S * 2 * kv * hd * (ctx.cache_bits / 8.0)
    comp = flops / (hw.peak_bf16 * chips)
    mem = cache_bytes / (hw.hbm_bw * chips)
    return UnitLatency(spec.name + ".attn", comp, mem)


def policy_latency(specs: Sequence[LayerSpec], policy: Policy,
                   hw: HardwareTarget = V5E,
                   ctx: Optional[LatencyContext] = None,
                   window: int = 0) -> PolicyLatency:
    ctx = ctx or LatencyContext(tokens=1, seq_ctx=1, mode="decode")
    fracs = _resolve_keep_fracs(specs, policy)
    out = PolicyLatency()
    n_ops = 0
    for s, c in zip(specs, policy.cmps):
        in_frac = fracs.get(s.dep_group, 1.0) if s.dep_group else 1.0
        out.units.append(unit_latency(s, c, in_frac, hw, ctx))
        n_ops += 1
        if s.kind == "attn_qkv" and ctx.seq_ctx > 0:
            out.units.append(_attention_extra(s, c, hw, ctx, window))
            n_ops += 1
    out.overhead_s = n_ops * hw.op_overhead
    return out


# ===========================================================================
# Compiled-HLO oracle (dry-run / §Roofline)
# ===========================================================================

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}


def _first_shape_bytes(line: str) -> float:
    """Bytes of the result shape(s) on an HLO instruction line (handles
    tuple results, e.g. reduce-scatter -> (f32[32], f32[32]))."""
    lhs = line.split(" = ", 1)
    target = lhs[1] if len(lhs) == 2 else line
    total = 0.0
    m = _COLLECTIVE_RE.search(target)
    head = target[:m.start()] if m else target.split("(", 1)[0]
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind over an HLO module."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or " = " not in line:
            continue
        if "-done" in line:  # avoid double counting async pairs
            continue
        kind = m.group(1)
        b = _first_shape_bytes(line)
        out[kind] = out.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    out["_counts"] = count
    return out


@dataclass
class RooflineReport:
    """Roofline terms from a compiled SPMD artifact.

    IMPORTANT semantics: ``cost_analysis()`` on a GSPMD-partitioned module
    reports PER-DEVICE flops/bytes (each device executes the partitioned
    program), and HLO shapes in the partitioned module are per-shard — so
    ``flops``/``bytes_accessed``/``collective_bytes`` here are per-chip.
    The spec formula  compute = HLO_FLOPs / (chips × peak)  is recovered
    because global HLO_FLOPs = per-chip × chips.  ``model_flops`` is GLOBAL
    (6·N·D over the full batch).
    """
    flops: float                       # per-chip
    bytes_accessed: float              # per-chip
    collective_bytes: float            # per-chip
    per_collective: dict
    chips: int
    hw: HardwareTarget
    model_flops: float = 0.0           # 6·N·D-style useful flops (global)

    @property
    def compute_s(self) -> float:
        return self.flops / self.hw.peak_bf16

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """GLOBAL useful flops / GLOBAL compiled flops (flops field is
        per-chip)."""
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modelled step
        time (useful-FLOPs MFU bound)."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops / self.step_s) / (self.hw.peak_bf16 *
                                                   self.chips)

    def summary(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_s, "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, hlo_text: Optional[str] = None,
                           chips: int = 1, hw: HardwareTarget = V5E,
                           model_flops: float = 0.0) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = hlo_collective_bytes(text)
    cbytes = sum(v for k, v in colls.items() if not k.startswith("_"))
    return RooflineReport(flops=flops, bytes_accessed=byts,
                          collective_bytes=cbytes, per_collective=colls,
                          chips=chips, hw=hw, model_flops=model_flops)
