"""Sensitivity analysis (paper Eq. 5, generalized ZeroQ) — fused.

For each layer and each probe CMP, compress ONLY that layer (reference
policy elsewhere) and measure the KL divergence between the compressed and
the original model's output distributions over N calibration samples:

    Ω(P) = 1/N Σ_j D_KL( M_P(θ;x_j) || M(θ;x_j) )

The full analysis runs once, up-front, for all layers (paper §Sensitivity);
results feed the agent state.

Every probe CMP is **legalized** first (``constraints.legalize`` — the
paper's TVM/ARM fallback rule): prune probes are rounded to the hardware
granularity via ``round_keep`` and quant probes fall back to INT8 where
``mix_allowed`` is False, so the KL features always describe policies the
agent can actually reach.

The probe evaluation itself is ONE jit execution per ``run_sensitivity``
call: all layer×probe single-layer policies are stacked into batched
(P, L) cspec arrays (the same traced-cspec builders
``accuracy_policy_batch`` shares — see ``compress.cspec_builder``), the
reference log-probs and every probe's KL are computed inside one
``jit`` whose probe loop is a ``lax.scan`` over vmapped probe blocks
(chunked to bound the live log-prob memory), and the (P,) KLs are
reduced on-device before the single host readback. ``run_sensitivity``
and ``full_sweep`` are both thin views over this fused core;
``run_sensitivity_sequential`` keeps the original one-dispatch-per-probe
path as the parity reference (mirroring the numpy-engine pattern of the
rollout engines), property-tested to ≤ 1e-6 per layer×probe KL in
``tests/test_sensitivity.py``.

Results are memoized per (cmodel, batch, params) identity, so every
engine constructor — and every member of a ``PopulationSearch`` built on
a common model — shares one analysis instead of re-running it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import legalize
from repro.core.latency import fifo_cached
from repro.core.policy import (Policy, PolicyBatch, policies_from_batch,
                               stack_policies)
from repro.core.spec import LayerCMP, LayerSpec, effective_bits


def kl_divergence(logp_c: jnp.ndarray, logp_o: jnp.ndarray) -> jnp.ndarray:
    """D_KL(compressed || original) averaged over batch (and positions)."""
    p_c = jnp.exp(logp_c)
    kl = jnp.sum(p_c * (logp_c - logp_o), axis=-1)
    return jnp.mean(kl)


# probe CMPs per method (paper: a predefined number of sample policies)
QUANT_W_PROBES = (8, 6, 4, 3, 2)
QUANT_A_PROBES = (8, 6, 4, 3, 2)
N_PRUNE_PROBES = 10

# the fixed probe set feeding the agent state (see SensitivityResult)
FEATURE_W_PROBES = (4, 2)
FEATURE_A_PROBES = (4, 2)
FEATURE_PRUNE_FRACS = (0.5, 0.25)
FEATURE_PROBES = ("w4", "w2", "a4", "a2", "p50", "p25")

# Legality-aware sentinel for probes that were never run (layer not
# quantizable / not prunable): a probed-and-robust layer reads 0.0
# (log1p(0)), an unprobed one reads MISSING_KL — the agent can tell
# "cannot be quantized" from "perfectly insensitive to quantization".
MISSING_KL = -1.0


@dataclass
class SensitivityResult:
    """per layer-spec name -> {probe_name: KL}"""
    table: Dict[str, Dict[str, float]]

    def feature(self, name: str, probe: str,
                default: float = MISSING_KL) -> float:
        """Raw KL for one probe; missing probes default to the
        ``MISSING_KL`` sentinel, consistent with ``feature_row``."""
        return self.table.get(name, {}).get(probe, default)

    def feature_row(self, name: str) -> np.ndarray:
        """(len(FEATURE_PROBES),) f32 probe features for one layer:
        log1p-squashed KLs, ``MISSING_KL`` where the probe was not run
        (not quantizable / not prunable — NOT the same as KL 0)."""
        row = self.table.get(name, {})
        return np.asarray(
            [np.log1p(row[k]) if k in row else MISSING_KL
             for k in FEATURE_PROBES], np.float32)

    def feature_rows(self, names: Sequence[str]) -> np.ndarray:
        """(len(names), len(FEATURE_PROBES)) array-form feature block —
        the form the state builders consume."""
        return np.stack([self.feature_row(n) for n in names])

    def features_for(self, name: str) -> List[float]:
        """Fixed-length probe feature vector for the agent state."""
        return [float(x) for x in self.feature_row(name)]


# ===========================================================================
# Probe plan: legalized layer×probe policies as stacked (P, L) arrays
# ===========================================================================

@dataclass(frozen=True)
class ProbeEntry:
    """One layer×probe row of a plan (bookkeeping for the result views)."""
    spec_idx: int
    layer: str
    method: str                # quant_w | quant_a | prune
    param: float               # bits (quant) or kept fraction (prune)
    tag: str                   # feature key, e.g. "w4" / "p50"


@dataclass
class ProbePlan:
    """All probes of one analysis in array form: row p of the (P, L)
    arrays is the reference policy with column ``entries[p].spec_idx``
    replaced by the **legalized** probe CMP (effective bits)."""
    entries: List[ProbeEntry]
    keep: np.ndarray           # (P, L) f64
    w_bits: np.ndarray         # (P, L) f64
    a_bits: np.ndarray         # (P, L) f64
    ref: Tuple[np.ndarray, np.ndarray, np.ndarray]   # (L,) each

    def __len__(self) -> int:
        return len(self.entries)


def build_probe_plan(specs: Sequence[LayerSpec],
                     w_probes: Sequence[int] = FEATURE_W_PROBES,
                     a_probes: Sequence[int] = FEATURE_A_PROBES,
                     prune_fracs: Sequence[float] = FEATURE_PRUNE_FRACS
                     ) -> ProbePlan:
    """Enumerate the layer×probe single-layer policies, each routed
    through ``legalize`` so the plan only contains reachable CMPs:
    probed keep counts obey ``round_keep`` (granularity-aligned, one
    granule floor) and MIX bit asks on ``mix_allowed``-False layers
    become the INT8 fallback instead of an illegal sub-8-bit policy."""
    ref_pb = stack_policies(specs, [Policy.reference(specs)])
    ref = (ref_pb.keep[0], ref_pb.w_bits[0], ref_pb.a_bits[0])
    entries: List[ProbeEntry] = []
    rows: List[Tuple[float, float, float]] = []

    def add(i: int, cmp: LayerCMP, method: str, param, tag: str):
        cmp = legalize(specs[i], cmp)
        w, a = effective_bits(cmp)
        entries.append(ProbeEntry(i, specs[i].name, method, param, tag))
        rows.append((float(cmp.keep), float(w), float(a)))

    for i, s in enumerate(specs):
        if s.quantizable:
            for b in w_probes:
                add(i, LayerCMP(keep=s.prune_dim, mode="MIX",
                                w_bits=int(b), a_bits=32),
                    "quant_w", b, f"w{int(b)}")
            for b in a_probes:
                add(i, LayerCMP(keep=s.prune_dim, mode="MIX",
                                w_bits=32, a_bits=int(b)),
                    "quant_a", b, f"a{int(b)}")
        if s.prunable and s.prune_dim:
            for frac in prune_fracs:
                add(i, LayerCMP(keep=max(1, int(s.prune_dim * float(frac)))),
                    "prune", float(frac),
                    f"p{int(round(float(frac) * 100))}")

    P, L = len(entries), len(specs)
    keep = np.tile(ref[0], (P, 1))
    wb = np.tile(ref[1], (P, 1))
    ab = np.tile(ref[2], (P, 1))
    for p, (e, row) in enumerate(zip(entries, rows)):
        keep[p, e.spec_idx], wb[p, e.spec_idx], ab[p, e.spec_idx] = row
    return ProbePlan(entries, keep, wb, ab, ref)


_plan_cache: dict = {}
_PLAN_CACHE_MAX = 256


def feature_probe_plan(specs: Sequence[LayerSpec]) -> ProbePlan:
    """The fixed agent-state probe plan, cached per spec-list identity."""
    hit = fifo_cached(
        _plan_cache, _PLAN_CACHE_MAX, id(specs),
        lambda h: h[0] is specs,
        lambda: (specs, build_probe_plan(specs)))
    return hit[1]


# ===========================================================================
# Fused core: every probe KL + the reference in ONE jit execution
# ===========================================================================

def _fused_kl_fn(cmodel, batch):
    """The jitted fused program, cached per (batch, params) identity on
    the adapter (same pattern as ``accuracy_policy_fn``'s cache —
    swapping in new weights must re-trace, since the traced builder
    bakes params and prune scores in as constants).

    Signature: ``(ref_k, ref_w, ref_a, keep, wb, ab) -> (P,) KLs`` with
    the probe arrays pre-chunked to (n_chunks, C, L). The reference
    log-probs are computed inside the same trace; the probe loop is a
    ``lax.scan`` over chunks of C vmapped probes, so peak live memory is
    C probe log-prob blocks, never P.
    """
    cached = getattr(cmodel, "_sens_kl_cache", None)
    if cached is not None and cached[0] is batch \
            and cached[1] is cmodel.params:
        return cached[2]
    build = cmodel.cspec_builder()

    def one_kl(logp_o, k, w, a):
        return kl_divergence(cmodel.log_probs(batch, build(k, w, a)),
                             logp_o)

    def fused(ref_k, ref_w, ref_a, keep, wb, ab):
        logp_o = cmodel.log_probs(batch, build(ref_k, ref_w, ref_a))

        def chunk(_, xs):
            k, w, a = xs
            return None, jax.vmap(
                lambda kk, ww, aa: one_kl(logp_o, kk, ww, aa))(k, w, a)

        _, kls = jax.lax.scan(chunk, None, (keep, wb, ab))
        return kls.reshape(-1)

    fn = jax.jit(fused)
    cmodel._sens_kl_cache = (batch, cmodel.params, fn)
    return fn


def _fused_dispatch(fn, *args):
    """Indirection for the compiled fused program — the benchmark's
    ``sensitivity_dispatch_probe`` wraps this to count real executions
    (the 1-per-analysis acceptance bound)."""
    return fn(*args)


def _seq_eval(fn, cspec):
    """Indirection for the sequential path's per-probe evaluations —
    wrapped as a canary by the dispatch probe (a fused analysis must
    never fall back to per-probe dispatches)."""
    return fn(cspec)


def _plan_kls(cmodel, batch, plan: ProbePlan, chunk: int) -> np.ndarray:
    """(P,) probe KLs for a plan — ONE jit execution, one readback.

    Legalization can collapse distinct probes onto one policy (all four
    quant probes of a ``mix_allowed``-False layer become the same INT8
    row), so identical rows are evaluated once and the KLs fanned back
    out. The unique rows are padded to a chunk multiple with reference
    rows (KL 0) so the scan consumes equal blocks; padding is dropped
    on the host."""
    P, L = plan.keep.shape
    if P == 0:
        return np.zeros((0,), np.float64)
    rows = np.concatenate([plan.keep, plan.w_bits, plan.a_bits], axis=1)
    uniq, inverse = np.unique(rows, axis=0, return_inverse=True)
    U = uniq.shape[0]
    chunk = max(1, min(int(chunk), U))
    pad = (-U) % chunk

    def prep(arr: np.ndarray, ref_row: np.ndarray) -> jnp.ndarray:
        if pad:
            arr = np.concatenate([arr, np.tile(ref_row, (pad, 1))])
        return jnp.asarray(arr.reshape(-1, chunk, L), jnp.int32)

    fn = _fused_kl_fn(cmodel, batch)
    ref = tuple(jnp.asarray(r, jnp.int32) for r in plan.ref)
    kls = _fused_dispatch(fn, *ref,
                          prep(uniq[:, :L], plan.ref[0]),
                          prep(uniq[:, L:2 * L], plan.ref[1]),
                          prep(uniq[:, 2 * L:], plan.ref[2]))
    return np.asarray(kls, np.float64)[:U][inverse.reshape(-1)]


def _result_from_plan(specs, plan: ProbePlan,
                      kls: np.ndarray) -> SensitivityResult:
    table: Dict[str, Dict[str, float]] = {s.name: {} for s in specs}
    for e, kl in zip(plan.entries, kls):
        table[e.layer][e.tag] = float(kl)
    return SensitivityResult(table)


# ===========================================================================
# Public views over the fused core
# ===========================================================================

_MEMO_CACHE_MAX = 8                    # per adapter instance
DEFAULT_CHUNK = 8


def run_sensitivity(cmodel, batch, chunk: int = DEFAULT_CHUNK,
                    memo: bool = True) -> SensitivityResult:
    """The agent-state analysis: legalized feature probes for every
    layer, evaluated as ONE jit execution (see the module docstring).

    ``cmodel``: CompressibleLM/CompressibleResNet; ``batch``:
    calibration data. ``memo=True`` (default) shares the result across
    callers with the same (cmodel, batch, params) identity — e.g. every
    engine constructor of a population built on one model. The memo
    lives ON the adapter (like ``_sens_kl_cache``), so it cannot extend
    the lifetime of models the caller has dropped.
    """
    plan = feature_probe_plan(cmodel.specs)

    def compute():
        kls = _plan_kls(cmodel, batch, plan, chunk)
        return (batch, cmodel.params,
                _result_from_plan(cmodel.specs, plan, kls))

    if not memo:
        return compute()[2]
    cache = getattr(cmodel, "_sens_memo", None)
    if cache is None:
        cache = cmodel._sens_memo = {}
    hit = fifo_cached(
        cache, _MEMO_CACHE_MAX, id(batch),
        lambda h: h[0] is batch and h[1] is cmodel.params,
        compute)
    return hit[2]


def run_sensitivity_sequential(cmodel, batch) -> SensitivityResult:
    """Parity reference: the same legalized probe plan, evaluated one
    jit dispatch per probe through the HOST cspec builder
    (``build_cspec``) — the original L×probe path. Kept (like the numpy
    rollout engines) purely so property tests can pin the fused core
    to it; production callers use ``run_sensitivity``.
    """
    plan = feature_probe_plan(cmodel.specs)
    kls = _plan_kls_sequential(cmodel, batch, plan)
    return _result_from_plan(cmodel.specs, plan, kls)


def _seq_logprobs_fn(cmodel, batch):
    """The sequential path's jitted log-probs, cached per
    (batch, params) identity like ``_fused_kl_fn`` — a fresh ``jax.jit``
    wrapper per call would defeat jit's callable-keyed cache and make
    every analysis (and every benchmark repeat) pay a re-trace."""
    cached = getattr(cmodel, "_sens_seq_cache", None)
    if cached is not None and cached[0] is batch \
            and cached[1] is cmodel.params:
        return cached[2]
    fn = jax.jit(lambda cs: cmodel.log_probs(batch, cs))
    cmodel._sens_seq_cache = (batch, cmodel.params, fn)
    return fn


def _plan_kls_sequential(cmodel, batch, plan: ProbePlan) -> np.ndarray:
    specs = cmodel.specs
    jit_lp = _seq_logprobs_fn(cmodel, batch)
    logp_o = _seq_eval(jit_lp,
                       cmodel.build_cspec(Policy.reference(specs)))
    pols = policies_from_batch(specs, PolicyBatch(
        keep=plan.keep, w_bits=plan.w_bits, a_bits=plan.a_bits))
    out = np.empty(len(pols), np.float64)
    for p, pol in enumerate(pols):
        logp_c = _seq_eval(jit_lp, cmodel.build_cspec(pol))
        out[p] = float(kl_divergence(logp_c, logp_o))
    return out


def full_sweep(cmodel, batch, w_bits=QUANT_W_PROBES, a_bits=QUANT_A_PROBES,
               n_prune: int = N_PRUNE_PROBES,
               chunk: int = DEFAULT_CHUNK) -> List[dict]:
    """Dense sweep used for the paper's Fig. 6 plots — a thin view over
    the same fused core as ``run_sensitivity`` (one jit execution for
    the whole layer×probe grid), with every probe legalized the same
    way."""
    plan = build_probe_plan(
        cmodel.specs, w_probes=w_bits, a_probes=a_bits,
        prune_fracs=tuple(float(f) for f in np.linspace(0.1, 1.0, n_prune)))
    kls = _plan_kls(cmodel, batch, plan, chunk)
    return [{"layer": e.layer, "method": e.method, "param": e.param,
             "kl": float(kl)} for e, kl in zip(plan.entries, kls)]
