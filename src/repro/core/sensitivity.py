"""Sensitivity analysis (paper Eq. 5, generalized ZeroQ).

For each layer and each probe CMP, compress ONLY that layer (reference
policy elsewhere) and measure the KL divergence between the compressed and
the original model's output distributions over N calibration samples:

    Ω(P) = 1/N Σ_j D_KL( M_P(θ;x_j) || M(θ;x_j) )

The full analysis runs once, up-front, for all layers (paper §Sensitivity);
results feed the agent state. One jitted evaluation serves every probe —
cspec bits/masks are traced values, so there is exactly one compile.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import Policy
from repro.core.spec import LayerCMP, LayerSpec


def kl_divergence(logp_c: jnp.ndarray, logp_o: jnp.ndarray) -> jnp.ndarray:
    """D_KL(compressed || original) averaged over batch (and positions)."""
    p_c = jnp.exp(logp_c)
    kl = jnp.sum(p_c * (logp_c - logp_o), axis=-1)
    return jnp.mean(kl)


# probe CMPs per method (paper: a predefined number of sample policies)
QUANT_W_PROBES = (8, 6, 4, 3, 2)
QUANT_A_PROBES = (8, 6, 4, 3, 2)
N_PRUNE_PROBES = 10


@dataclass
class SensitivityResult:
    """per layer-spec name -> {probe_name: KL}"""
    table: Dict[str, Dict[str, float]]

    def feature(self, name: str, probe: str, default: float = 0.0) -> float:
        return self.table.get(name, {}).get(probe, default)

    def features_for(self, name: str) -> List[float]:
        """Fixed-length probe feature vector for the agent state
        (log1p-squashed KLs)."""
        row = self.table.get(name, {})
        keys = (["w4", "w2", "a4", "a2"] +
                ["p50", "p25"])
        return [float(np.log1p(row.get(k, 0.0))) for k in keys]


def run_sensitivity(cmodel, batch, jit_logprobs=None) -> SensitivityResult:
    """cmodel: CompressibleLM/CompressibleResNet; batch: calibration data."""
    specs: Sequence[LayerSpec] = cmodel.specs
    ref = Policy.reference(specs)

    if jit_logprobs is None:
        jit_logprobs = jax.jit(
            lambda cs: cmodel.log_probs(batch, cs))
    base_cspec = cmodel.build_cspec(ref)
    logp_o = jit_logprobs(base_cspec)

    def probe_kl(policy: Policy) -> float:
        cs = cmodel.build_cspec(policy)
        logp_c = jit_logprobs(cs)
        return float(kl_divergence(logp_c, logp_o))

    table: Dict[str, Dict[str, float]] = {}
    for i, s in enumerate(specs):
        row: Dict[str, float] = {}
        if s.quantizable:
            for b in (4, 2):
                pol = copy.deepcopy(ref)
                pol.cmps[i] = LayerCMP(keep=s.prune_dim, mode="MIX",
                                       w_bits=b, a_bits=32)
                row[f"w{b}"] = probe_kl(pol)
                pol = copy.deepcopy(ref)
                pol.cmps[i] = LayerCMP(keep=s.prune_dim, mode="MIX",
                                       w_bits=32, a_bits=b)
                row[f"a{b}"] = probe_kl(pol)
        if s.prunable and s.prune_dim:
            for frac, tag in ((0.5, "p50"), (0.25, "p25")):
                pol = copy.deepcopy(ref)
                keep = max(1, int(s.prune_dim * frac))
                pol.cmps[i] = LayerCMP(keep=keep)
                row[tag] = probe_kl(pol)
        table[s.name] = row
    return SensitivityResult(table)


def full_sweep(cmodel, batch, w_bits=QUANT_W_PROBES, a_bits=QUANT_A_PROBES,
               n_prune: int = N_PRUNE_PROBES):
    """Dense sweep used for the paper's Fig. 6 plots (slower)."""
    specs = cmodel.specs
    ref = Policy.reference(specs)
    jit_logprobs = jax.jit(lambda cs: cmodel.log_probs(batch, cs))
    logp_o = jit_logprobs(cmodel.build_cspec(ref))

    rows = []
    for i, s in enumerate(specs):
        if s.quantizable:
            for b in w_bits:
                pol = copy.deepcopy(ref)
                pol.cmps[i] = LayerCMP(keep=s.prune_dim, mode="MIX",
                                       w_bits=b, a_bits=32)
                kl = float(kl_divergence(
                    jit_logprobs(cmodel.build_cspec(pol)), logp_o))
                rows.append({"layer": s.name, "method": "quant_w",
                             "param": b, "kl": kl})
            for b in a_bits:
                pol = copy.deepcopy(ref)
                pol.cmps[i] = LayerCMP(keep=s.prune_dim, mode="MIX",
                                       w_bits=32, a_bits=b)
                kl = float(kl_divergence(
                    jit_logprobs(cmodel.build_cspec(pol)), logp_o))
                rows.append({"layer": s.name, "method": "quant_a",
                             "param": b, "kl": kl})
        if s.prunable and s.prune_dim:
            for frac in np.linspace(0.1, 1.0, n_prune):
                pol = copy.deepcopy(ref)
                pol.cmps[i] = LayerCMP(keep=max(1, int(s.prune_dim * frac)))
                kl = float(kl_divergence(
                    jit_logprobs(cmodel.build_cspec(pol)), logp_o))
                rows.append({"layer": s.name, "method": "prune",
                             "param": float(frac), "kl": kl})
    return rows
