"""Measured-latency subsystem — the repo's stand-in for the paper's
compile-and-measure loop (Galen compiles each candidate policy with TVM
and times it on the ARM core; AMC found analytic proxies materially
mis-rank policies).

Three layers, bottom-up:

* **Unit measurement** (`measure_unit_rows`) — for every layer spec,
  build the *deploy-path* op the policy would actually execute
  (``deploy.quantize_weight`` container -> ``layers.materialize_weight``
  -> einsum; a gather for embeddings) in each weight container
  (raw / int8 / packed int4), time it with warmup + ``block_until_ready``
  fencing, and record measured seconds next to the analytic roofline term
  for the same (spec, container).

* **Calibration** (`fit_calibration` -> `CalibrationTable`) — per
  (layer kind, container) geometric-mean measured/analytic ratios, plus
  a lumped residual factor for the attention extras + dispatch overhead
  fitted from a whole-model measurement. The table is JSON-serialized as
  ``artifacts/latency_calibration.json`` (benchmarks/calibrate_oracle.py)
  and consumed by all three oracle forms via their ``calib=`` argument:
  the factors bake into the ``JaxBatchOracle`` trace as constants, so
  ``oracle_mode="calibrated"`` keeps the fused rollout at its
  single-dispatch bound.

* **Policy measurement** (`measure_policy`) — deploy a full search
  policy onto integer containers (per-unit-kind bit widths through
  ``quantize_params_for_deploy(bits_for=...)``) and wall-clock the jitted
  deployed forward. FIFO-memoized by the policy's container signature so
  ``oracle_mode="measured"`` re-times only distinct top-K candidates.

Deployment note: on scan-stacked models the per-layer weights share one
stacked array per name, so a policy deploys at the WIDEST container any
layer of that name asks for (conservative), and structured pruning is
not materialized — measured mode times the quantization decision, which
is the part the analytic oracle models per-container.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deploy import quantize_params_for_deploy, quantize_weight
from repro.core.latency import (CONTAINERS, HardwareTarget, LatencyContext,
                                V5E, container_for_bits, fifo_cached,
                                policy_latency, roofline_from_compiled,
                                unit_latency)
from repro.core.policy import Policy
from repro.core.spec import LayerCMP, LayerSpec, effective_bits

DEFAULT_CALIBRATION_PATH = "artifacts/latency_calibration.json"

# Container -> the LayerCMP whose analytic term the measurement is
# compared against (full width kept; the containers differ only in
# weight storage, which is exactly what the deploy path changes).
CONTAINER_BITS = {"raw": None, "int8": 8, "int4": 4}


def _container_cmp(spec: LayerSpec, container: str) -> LayerCMP:
    keep = spec.prune_dim if spec.prune_dim else 0
    if container == "raw":
        return LayerCMP(keep=keep, mode="FP32")
    if container == "int8":
        return LayerCMP(keep=keep, mode="INT8", w_bits=8, a_bits=8)
    return LayerCMP(keep=keep, mode="MIX", w_bits=4, a_bits=4)


@dataclass(frozen=True)
class MeasureConfig:
    warmup: int = 2
    repeats: int = 5
    tokens: int = 64          # rows fed to each unit op (the m dimension)
    seed: int = 0


def time_best(fn: Callable[[], object], warmup: int = 2,
              repeats: int = 5) -> float:
    """Best-of-N wall clock with warmup and ``block_until_ready`` fencing
    (best-of filters scheduler noise better than mean on shared CI)."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


# ===========================================================================
# Unit measurement
# ===========================================================================

def _unit_dims(spec: LayerSpec) -> tuple:
    """(k, n) of the dense-equivalent matmul a unit executes on the
    deploy path. Convs are their im2col view; gated MLPs fold the
    up+gate matmuls into one widened n (same FLOPs/bytes the analytic
    unit charges)."""
    if spec.kind == "conv":
        k = int(round(spec.weight_elems / max(1, spec.out_dim)))
        return k, int(spec.out_dim)
    if spec.kind == "embed":
        return int(spec.in_dim), int(spec.out_dim)      # vocab rows, d cols
    k = int(spec.in_dim)
    return k, int(round(spec.weight_elems / max(1, k)))


def _unit_callable(spec: LayerSpec, container: str, m: int, key):
    """Jitted deploy-path op for one (spec, container): materialize the
    integer container and run the consuming op, exactly as
    ``models/layers.py`` does at serving time."""
    from repro.models.layers import materialize_weight

    k, n = _unit_dims(spec)
    kw, kx = jax.random.split(key)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    p = {"w": w} if container == "raw" \
        else quantize_weight(w, CONTAINER_BITS[container])
    if spec.kind == "embed":
        ids = jax.random.randint(kx, (m,), 0, k)
        fn = jax.jit(lambda p, i: jnp.take(
            materialize_weight(p, jnp.float32), i, axis=0))
        args = (p, ids)
    else:
        x = jax.random.normal(kx, (m, k), jnp.float32)
        fn = jax.jit(lambda p, x: x @ materialize_weight(p, x.dtype))
        args = (p, x)
    return lambda: fn(*args)


def measure_unit_rows(specs: Sequence[LayerSpec],
                      hw: HardwareTarget = V5E,
                      ctx: Optional[LatencyContext] = None,
                      cfg: MeasureConfig = MeasureConfig()) -> list:
    """Measured-vs-analytic rows per (unique unit shape, container).

    MoE expert stacks have no dense 2-D equivalent (analytic FLOPs count
    ``top_k`` active experts, storage counts all) and fall back to the
    1.0 factor — the skip is recorded as an explicit row so the artifact
    never silently reads as full coverage.
    """
    ctx = ctx or LatencyContext(tokens=cfg.tokens, seq_ctx=0, mode="prefill")
    mctx = dataclasses.replace(ctx, tokens=cfg.tokens)
    rows, seen = [], {}
    key = jax.random.PRNGKey(cfg.seed)
    for spec in specs:
        if spec.kind in ("moe_up", "moe_down"):
            rows.append({"kind": spec.kind, "name": spec.name,
                         "skipped": "stacked expert weights"})
            continue
        k, n = _unit_dims(spec)
        for container in CONTAINERS:
            if container == "int4" and k % 2:
                rows.append({"kind": spec.kind, "name": spec.name,
                             "container": container,
                             "skipped": "odd contraction dim"})
                continue
            sig = (spec.kind, k, n, container)
            if sig in seen:         # scan-stacked layers repeat shapes
                continue
            key, sub = jax.random.split(key)
            t = time_best(_unit_callable(spec, container, cfg.tokens, sub),
                          cfg.warmup, cfg.repeats)
            ana = unit_latency(spec, _container_cmp(spec, container),
                               1.0, hw, mctx).time_s
            seen[sig] = True
            rows.append({"kind": spec.kind, "name": spec.name,
                         "container": container, "k": k, "n": n,
                         "m": cfg.tokens, "measured_s": t,
                         "analytic_s": ana,
                         "ratio": t / ana if ana > 0 else float("inf")})
    return rows


def measure_kernel_rows(cfg: MeasureConfig = MeasureConfig(),
                        dims: tuple = (256, 256, 256)) -> list:
    """Informational rows timing the actual Pallas ``quant_matmul``
    int8/int4 kernels against the dense f32 matmul of the same shape.
    (The deployed forward uses the dequantize-into-matmul path measured
    above; these rows track the kernel alternative — in interpret mode
    on CPU they are orders of magnitude off real TPU numbers.)"""
    from repro.kernels import ops

    M, K, N = dims
    kx, kw = jax.random.split(jax.random.PRNGKey(cfg.seed))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    dense = jax.jit(lambda x, w: x @ w)
    rows = [{"kernel": "dense_f32", "M": M, "K": K, "N": N,
             "measured_s": time_best(lambda: dense(x, w),
                                     cfg.warmup, cfg.repeats)}]
    for bits, name in ((8, "quant_matmul_int8"), (4, "quant_matmul_int4")):
        t = time_best(lambda: ops.quantized_matmul(x, w, w_bits=bits),
                      cfg.warmup, cfg.repeats)
        rows.append({"kernel": name, "M": M, "K": K, "N": N,
                     "measured_s": t})
    return rows


# ===========================================================================
# Calibration table
# ===========================================================================

@dataclass
class CalibrationTable:
    """Measured/analytic correction factors, keyed (kind, container).

    ``ratios[kind][container]`` scales that unit's roofline term;
    ``extra["attn"]`` scales the attention score/AV + KV-cache extras and
    ``extra["overhead"]`` the per-op dispatch overhead (both lumped
    residuals from a whole-model fit). Unknown kinds/containers fall back
    to 1.0, so a partial table degrades to the analytic oracle.
    """
    ratios: dict
    extra: dict = field(default_factory=dict)
    meta: dict = field(default_factory=dict)

    def factor(self, kind: str, container: str) -> float:
        return float(self.ratios.get(kind, {}).get(container, 1.0))

    def extra_factor(self) -> float:
        return float(self.extra.get("attn", 1.0))

    def overhead_factor(self) -> float:
        return float(self.extra.get("overhead", 1.0))

    def unit_factors(self, specs: Sequence[LayerSpec]) -> np.ndarray:
        """(L, 3) per-spec factors in ``latency.CONTAINERS`` column
        order — the array the batch oracles index by container bucket."""
        out = np.ones((len(specs), len(CONTAINERS)), np.float64)
        for i, s in enumerate(specs):
            for j, c in enumerate(CONTAINERS):
                out[i, j] = self.factor(s.kind, c)
        return out

    def to_dict(self) -> dict:
        return {"ratios": self.ratios, "extra": self.extra, "meta": self.meta}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationTable":
        return cls(ratios=d.get("ratios", {}), extra=d.get("extra", {}),
                   meta=d.get("meta", {}))

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def load_calibration(path: Optional[str] = None) -> CalibrationTable:
    """Load the committed calibration artifact (default path relative to
    the repo root / benchmark cwd)."""
    try:
        return CalibrationTable.load(path or DEFAULT_CALIBRATION_PATH)
    except FileNotFoundError:
        raise FileNotFoundError(
            f"calibration artifact not found at "
            f"{path or DEFAULT_CALIBRATION_PATH!r} — generate it with "
            f"`python -m benchmarks.calibrate_oracle` or pass calib= "
            f"explicitly") from None


def fit_calibration(unit_rows: Sequence[dict],
                    meta: Optional[dict] = None) -> CalibrationTable:
    """Geometric-mean measured/analytic ratio per (kind, container)."""
    logs: dict = {}
    for r in unit_rows:
        if "ratio" not in r or not np.isfinite(r["ratio"]) or r["ratio"] <= 0:
            continue
        logs.setdefault(r["kind"], {}).setdefault(
            r["container"], []).append(np.log(r["ratio"]))
    ratios = {k: {c: float(np.exp(np.mean(v))) for c, v in d.items()}
              for k, d in logs.items()}
    return CalibrationTable(ratios=ratios, meta=meta or {})


def fit_extra_factor(table: CalibrationTable, specs: Sequence[LayerSpec],
                     ref_policy: Policy, measured_total_s: float,
                     hw: HardwareTarget, ctx: LatencyContext,
                     window: int = 0) -> None:
    """Fit the lumped attention/overhead residual in place: whatever the
    whole-model measurement shows beyond the calibrated unit terms is
    attributed to the extras (attention score/AV, norms, dispatch).
    Existing extra factors are reset first so the fit is computed
    against unit-factor extras — refitting is idempotent."""
    table.extra["attn"] = table.extra["overhead"] = 1.0
    pl = policy_latency(specs, ref_policy, hw, ctx, window, calib=table)
    unit_s = sum(u.time_s for u in pl.units if not u.name.endswith(".attn"))
    extra_s = sum(u.time_s for u in pl.units if u.name.endswith(".attn"))
    extra_s += pl.overhead_s
    if extra_s > 0:
        f = max(0.0, (measured_total_s - unit_s)) / extra_s
        table.extra["attn"] = f
        table.extra["overhead"] = f


# ===========================================================================
# Whole-policy deployment + measurement
# ===========================================================================

def spec_param_names(spec: LayerSpec) -> tuple:
    """Param-tree weight names a spec's policy decision governs (the
    names ``quantize_params_for_deploy`` keys containers by)."""
    k = spec.kind
    if k == "embed":
        return ("embed",)
    if k == "head":
        return ("unembed", "head")
    if k == "attn_qkv":
        return ("wq", "wk", "wv")
    if k == "attn_out":
        return ("wo",)
    if k == "mlp_up":
        return ("dense_w_up", "dense_w_gate") \
            if spec.extra.get("dense_residual") else ("w_up", "w_gate")
    if k == "mlp_down":
        return ("dense_w_down",) \
            if spec.extra.get("dense_residual") else ("w_down",)
    if k == "moe_up":
        return ("w_up", "w_gate")
    if k == "moe_down":
        return ("w_down",)
    if k == "ssm_in":
        return ("in_proj",)
    if k == "ssm_out":
        return ("out_proj",)
    if k == "rglru_in":
        return ("w_x", "w_y")
    if k == "rglru_out":
        return ("w_out",)
    if k == "conv":
        return ("stem", "conv1", "conv2", "skip")
    return ()


def policy_bits_by_name(specs: Sequence[LayerSpec],
                        policy: Policy) -> dict:
    """Weight name -> deployed bit width (>8 = raw). Scan-stacked models
    share one array per name across layers, so the WIDEST width any
    layer asks for wins — deployment never quantizes a layer harder than
    its policy allows."""
    bits: dict = {}
    for s, c in zip(specs, policy.cmps):
        wb, _ = effective_bits(c)
        for name in spec_param_names(s):
            bits[name] = max(bits.get(name, 0), int(wb))
    return bits


def deploy_policy_params(cmodel, policy: Policy):
    """Materialize a search policy's quantization decisions as real
    integer weight containers on the model's params."""
    bits = policy_bits_by_name(cmodel.specs, policy)
    return quantize_params_for_deploy(cmodel.params,
                                      bits_for=lambda n: bits.get(n))


def _deployed_forward(cmodel):
    """(fn(qp, batch), batch-arg extractor) for the deployed forward of
    an LM or ResNet compressible model."""
    cfg = cmodel.cfg
    if hasattr(cfg, "vocab_size"):
        from repro.models import model as M
        return lambda qp, batch: M.forward(cfg, qp, tokens=batch["tokens"])
    from repro.models import resnet as R
    return lambda qp, batch: R.forward(cfg, qp, batch["images"])


_measure_memo: dict = {}
_MEASURE_MEMO_MAX = 32


def measure_policy(cmodel, policy: Policy, batch: dict,
                   cfg: MeasureConfig = MeasureConfig()) -> float:
    """Wall-clock seconds of the jitted deployed forward under
    ``policy``'s containers. FIFO-memoized on (model params, batch,
    container signature): ``oracle_mode="measured"`` re-times only
    distinct top-K candidates, and repeated winners are free."""
    bits = policy_bits_by_name(cmodel.specs, policy)
    sig = tuple(sorted((n, container_for_bits(b)) for n, b in bits.items()))
    key = (id(cmodel.params), id(batch), sig, cfg)

    def factory():
        qp = quantize_params_for_deploy(cmodel.params,
                                        bits_for=lambda n: bits.get(n))
        fwd = jax.jit(_deployed_forward(cmodel))
        t = time_best(lambda: fwd(qp, batch), cfg.warmup, cfg.repeats)
        # hold refs so the identity key can't be recycled under us
        return (cmodel.params, batch, t)

    hit = fifo_cached(_measure_memo, _MEASURE_MEMO_MAX, key,
                      lambda h: h[0] is cmodel.params and h[1] is batch,
                      factory)
    return hit[2]


def measure_model_row(cmodel, batch: dict, container: str,
                      cfg: MeasureConfig = MeasureConfig()) -> dict:
    """Whole-model deployed-forward measurement for a uniform container,
    with ``roofline_from_compiled`` cost extraction on the compiled
    artifact. Deploys through ``uniform_policy`` so the measurement and
    the calibrated oracle's prediction describe the same containers
    (mix-unsupported embed/head ride int8 in the "int4" row)."""
    qp = cmodel.params if container == "raw" else deploy_policy_params(
        cmodel, uniform_policy(cmodel.specs, container))
    fwd = jax.jit(_deployed_forward(cmodel))
    compiled = fwd.lower(qp, batch).compile()
    t = time_best(lambda: fwd(qp, batch), cfg.warmup, cfg.repeats)
    rep = roofline_from_compiled(compiled)
    return {"container": container, "measured_s": t,
            "roofline": rep.summary()}


def uniform_policy(specs: Sequence[LayerSpec], container: str) -> Policy:
    """Uniform-quantization policy matching ``measure_model_row``'s
    deployment: INT8 everywhere for "int8"; 4-bit MIX where supported
    (INT8 on mix-unsupported embed/head) for "int4"."""
    pol = Policy.reference(specs)
    if container == "raw":
        return pol
    for s, c in zip(specs, pol.cmps):
        if not s.quantizable:
            continue
        if container == "int8" or not s.mix_supported:
            c.mode, c.w_bits, c.a_bits = "INT8", 8, 8
        else:
            c.mode, c.w_bits, c.a_bits = "MIX", 4, 4
    return pol
