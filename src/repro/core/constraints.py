"""Hardware legality checks for compression-method parameters (CMPs).

The paper's TVM/ARM analogue: bit-serial operators require input channels
% 32, output channels % 8, no depthwise, spatial >= 2 — and layers failing
the check fall back to INT8. Our TPU v5e analogue:

  * MXU lane width is 128 — pruned dims are rounded so the *kept* count is a
    multiple of the unit's ``prune_granularity`` (picked per layer so that
    kept*head_dim etc. stays 128-aligned); otherwise the MXU pads and the
    pruning buys nothing (the latency oracle models that padding).
  * MIX (sub-8-bit) weights only pay off via int4 packing, which needs the
    contracted dim 256-aligned; layers that cannot satisfy it get INT8.
  * Embedding/unembedding (first/last layers): INT8-or-FP32 only — same
    restriction the paper hits on ARM for first/last conv.
  * Sub-8-bit *activations* are emulated (fake-quant) on TPU: allowed for
    accuracy but the oracle grants them no compute speedup beyond int8.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.spec import LayerCMP, LayerSpec

MXU_LANE = 128
INT4_ALIGN = 256


def round_keep(spec: LayerSpec, keep: int) -> int:
    """Round a kept-channel count down to the hardware granularity
    (>= one granule)."""
    g = max(1, spec.prune_granularity)
    keep = max(g, (keep // g) * g)
    return min(keep, spec.prune_dim)


def mix_allowed(spec: LayerSpec) -> bool:
    if not spec.mix_supported or not spec.quantizable:
        return False
    # int4 weight packing wants the contraction dim 256-aligned
    return spec.in_dim % INT4_ALIGN == 0 or spec.kind == "conv"


def legalize(spec: LayerSpec, cmp: LayerCMP) -> LayerCMP:
    """Clamp a proposed CMP to what the hardware target supports."""
    if spec.prunable and spec.prune_dim:
        cmp.keep = round_keep(spec, cmp.keep)
    else:
        cmp.keep = spec.prune_dim
    if not spec.quantizable:
        cmp.mode, cmp.w_bits, cmp.a_bits = "FP32", 32, 32
    elif cmp.mode == "MIX" and not mix_allowed(spec):
        # paper: unsupported layers take the INT8 option instead
        cmp.mode, cmp.w_bits, cmp.a_bits = "INT8", 8, 8
    return cmp


# ===========================================================================
# Array form — the same legality rules as data, for vectorized mapping
# ===========================================================================

class LegalTables(NamedTuple):
    """Per-spec legality parameters as float32/bool arrays (one entry per
    ``LayerSpec``), the table form consumed by ``map_actions_batch`` and
    the fused rollout scan.  All entries are plain numpy: they are
    policy-independent constants that bake into a jit trace."""
    prune_dim: np.ndarray      # (L,) f32
    granularity: np.ndarray    # (L,) f32  (>= 1)
    prunable: np.ndarray       # (L,) bool  (prunable AND prune_dim > 0)
    quantizable: np.ndarray    # (L,) bool
    mix_ok: np.ndarray         # (L,) bool  (mix_allowed per spec)


def legal_tables(specs: Sequence[LayerSpec]) -> LegalTables:
    return LegalTables(
        prune_dim=np.asarray([s.prune_dim for s in specs], np.float32),
        granularity=np.asarray(
            [max(1, s.prune_granularity) for s in specs], np.float32),
        prunable=np.asarray([bool(s.prunable and s.prune_dim)
                             for s in specs]),
        quantizable=np.asarray([s.quantizable for s in specs]),
        mix_ok=np.asarray([mix_allowed(s) for s in specs]))


def round_keep_arrays(keep, granularity, prune_dim):
    """``round_keep`` as array ops (jnp; traceable): round kept counts
    down to the granularity, floor one granule, cap at the prunable
    dim.  Inputs broadcast; counts stay exact in f32."""
    rounded = jnp.maximum(jnp.floor(keep / granularity) * granularity,
                          granularity)
    return jnp.minimum(rounded, prune_dim)
