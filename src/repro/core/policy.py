"""Continuous policy -> discrete CMP mapping (paper Eq. 1, 4, 8).

A *policy* is the per-layer list of continuous compression parameters in
[0,1] (Eq. 1). Actions from the agents are mapped:

  * pruning: Eq. 4 inverse mapping  d_v(r) = floor((1-r) * v) + 1
  * quantization: threshold selection (Eq. 8) with t_mix=0.5, t_int8=0.2,
    then Eq. 4 against the max mix bit width (6 — see quantization.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import constraints
from repro.core.quantization import MAX_MIX_BITS
from repro.core.spec import LayerCMP, LayerSpec, effective_bits

T_MIX = 0.5
T_INT8 = 0.2


def n_actions(methods: str) -> int:
    """Action-vector length per method set (paper: r_p / r_w,r_a / all 3)."""
    return {"p": 1, "q": 2, "pq": 3}[methods]


def d_inverse(r: float, v: int) -> int:
    """Paper Eq. 4: continuous ratio r in [0,1] -> discrete value in [1, v]."""
    return int(np.floor((1.0 - r) * v)) + 1 if v > 0 else 0


def scale_mix_action(a: float) -> float:
    """Paper Eq. 8 (with the min/max order fixed — the printed equation's
    clip bounds are transposed): r = clip((a - t_mix)/(1 - t_mix), 0, 1)."""
    return float(np.clip((a - T_MIX) / (1.0 - T_MIX), 0.0, 1.0))


def quant_cmp_from_actions(a_w: float, a_a: float,
                           max_bits: int = MAX_MIX_BITS) -> LayerCMP:
    """Threshold-based quant-mode selection (paper §Quantization details)."""
    if max(a_w, a_a) > T_MIX:
        # r is a *compression ratio*: r=0 -> max_bits, r=1 -> 1 bit (Eq. 4)
        r_w, r_a = scale_mix_action(a_w), scale_mix_action(a_a)
        return LayerCMP(keep=0, mode="MIX",
                        w_bits=min(d_inverse(r_w, max_bits), max_bits),
                        a_bits=min(d_inverse(r_a, max_bits), max_bits))
    if max(a_w, a_a) > T_INT8:
        return LayerCMP(keep=0, mode="INT8", w_bits=8, a_bits=8)
    return LayerCMP(keep=0, mode="FP32", w_bits=32, a_bits=32)


def prune_keep_from_action(spec: LayerSpec, a_p: float) -> int:
    """Action -> kept channel count (Eq. 4 with v = original count)."""
    if not spec.prunable or spec.prune_dim == 0:
        return spec.prune_dim
    return min(d_inverse(float(a_p), spec.prune_dim), spec.prune_dim)


def map_actions(spec: LayerSpec, actions: Sequence[float],
                methods: str) -> LayerCMP:
    """methods: "p" (prune), "q" (quant) or "pq" (joint)."""
    if methods == "p":
        cmp = LayerCMP(keep=prune_keep_from_action(spec, actions[0]))
    elif methods == "q":
        cmp = quant_cmp_from_actions(actions[0], actions[1])
        cmp.keep = spec.prune_dim
    elif methods == "pq":
        cmp = quant_cmp_from_actions(actions[1], actions[2])
        cmp.keep = prune_keep_from_action(spec, actions[0])
    else:
        raise ValueError(methods)
    return constraints.legalize(spec, cmp)


def action_columns(methods: str) -> tuple[int, int, int]:
    """(prune, w-quant, a-quant) column indices into the action vector.
    Dead columns point at index 0 and are masked off downstream (the
    fused rollout carries do_p/do_q flags) — this keeps the traced step
    function method-agnostic, so one compiled form serves p/q/pq and
    mixed-method populations vmap together."""
    if methods == "p":
        return (0, 0, 0)
    if methods == "q":
        return (0, 0, 1)
    if methods == "pq":
        return (0, 1, 2)
    raise ValueError(methods)


def map_actions_batch(actions, *, prune_dim, granularity, prunable,
                      quantizable, mix_ok, ip=0, iw=1, ia=2):
    """Vectorized ``map_actions`` + ``legalize`` over K action rows for
    ONE spec: (K, A) actions -> (keep, w_bits, a_bits) arrays of
    *effective* bits (the ``PolicyBatch`` form).

    The spec parameters are scalars (or 0-d arrays — the fused rollout
    gathers them from ``constraints.legal_tables`` at a traced index);
    ``ip``/``iw``/``ia`` are the action columns per ``action_columns``.
    Matches the scalar path element-for-element: Eq. 4 inverse mapping,
    Eq. 8 thresholds, then the hardware legalization (granularity
    rounding, MIX->INT8 fallback, non-quantizable->FP32).
    """
    actions = jnp.asarray(actions, jnp.float32)
    a_p, a_w, a_a = actions[..., ip], actions[..., iw], actions[..., ia]

    # --- pruning: d_inverse(a_p, prune_dim), rounded to the granularity
    raw = jnp.floor((1.0 - a_p) * prune_dim) + 1.0
    keep = jnp.minimum(raw, prune_dim)
    keep = constraints.round_keep_arrays(keep, granularity, prune_dim)
    keep = jnp.where(prunable, keep, prune_dim)

    # --- quantization: threshold mode selection + Eq. 4 on mix bits
    hi = jnp.maximum(a_w, a_a)
    is_mix = hi > T_MIX
    is_int8 = ~is_mix & (hi > T_INT8)
    r_w = jnp.clip((a_w - T_MIX) / (1.0 - T_MIX), 0.0, 1.0)
    r_a = jnp.clip((a_a - T_MIX) / (1.0 - T_MIX), 0.0, 1.0)
    mix_w = jnp.minimum(jnp.floor((1.0 - r_w) * MAX_MIX_BITS) + 1.0,
                        float(MAX_MIX_BITS))
    mix_a = jnp.minimum(jnp.floor((1.0 - r_a) * MAX_MIX_BITS) + 1.0,
                        float(MAX_MIX_BITS))
    # MIX on a spec that cannot pack int4 falls back to INT8 (legalize)
    is_int8 = is_int8 | (is_mix & ~mix_ok)
    is_mix = is_mix & mix_ok
    wb = jnp.where(is_mix, mix_w, jnp.where(is_int8, 8.0, 32.0))
    ab = jnp.where(is_mix, mix_a, jnp.where(is_int8, 8.0, 32.0))
    wb = jnp.where(quantizable, wb, 32.0)
    ab = jnp.where(quantizable, ab, 32.0)
    return keep, wb, ab


@dataclass
class Policy:
    """A complete compression policy for a model (one CMP per LayerSpec)."""
    cmps: List[LayerCMP] = field(default_factory=list)

    @staticmethod
    def reference(specs: Sequence[LayerSpec]) -> "Policy":
        """P_r — the initial no-compression policy."""
        return Policy([LayerCMP(keep=s.prune_dim) for s in specs])

    def macs_fraction(self, specs: Sequence[LayerSpec]) -> float:
        tot = sum(s.flops_per_token for s in specs) or 1.0
        acc = 0.0
        for s, c in zip(specs, self.cmps):
            f_out = (c.keep / s.prune_dim) if s.prune_dim else 1.0
            acc += s.flops_per_token * f_out
        return acc / tot

    def bops(self, specs: Sequence[LayerSpec]) -> float:
        """Bit operations: MACs * w_bits * a_bits (Baskin et al. 2021)."""
        acc = 0.0
        for s, c in zip(specs, self.cmps):
            f_out = (c.keep / s.prune_dim) if s.prune_dim else 1.0
            acc += s.flops_per_token / 2.0 * f_out * c.w_bits * c.a_bits
        return acc

    n_actions = staticmethod(n_actions)   # back-compat alias


@dataclass
class PolicyBatch:
    """K policies over the same LayerSpec list, as (K, L) arrays.

    ``keep`` holds kept counts; ``w_bits``/``a_bits`` hold *effective*
    bits (mode already resolved) — the form the vectorized latency
    oracle consumes.
    """
    keep: np.ndarray
    w_bits: np.ndarray
    a_bits: np.ndarray

    def __len__(self) -> int:
        return self.keep.shape[0]


def policies_from_batch(specs: Sequence[LayerSpec],
                        batch: PolicyBatch) -> List[Policy]:
    """Inverse of ``stack_policies``. Effective bits map back to modes
    uniquely: (32,32) -> FP32, (8,8) -> INT8, anything else is MIX
    (mix bits are capped at ``MAX_MIX_BITS`` < 8 by Eq. 8)."""
    out = []
    for k in range(len(batch)):
        cmps = []
        for i in range(len(specs)):
            w = int(round(float(batch.w_bits[k, i])))
            a = int(round(float(batch.a_bits[k, i])))
            keep = int(round(float(batch.keep[k, i])))
            if w >= 32 and a >= 32:
                cmps.append(LayerCMP(keep=keep))
            elif w == 8 and a == 8:
                cmps.append(LayerCMP(keep=keep, mode="INT8", w_bits=8,
                                     a_bits=8))
            else:
                cmps.append(LayerCMP(keep=keep, mode="MIX", w_bits=w,
                                     a_bits=a))
        out.append(Policy(cmps))
    return out


def stack_policies(specs: Sequence[LayerSpec],
                   policies: Sequence[Policy]) -> PolicyBatch:
    """Pack K policies into the array form of ``PolicyBatch``."""
    K, L = len(policies), len(specs)
    keep = np.zeros((K, L), np.float64)
    wb = np.zeros((K, L), np.float64)
    ab = np.zeros((K, L), np.float64)
    for k, p in enumerate(policies):
        for i, c in enumerate(p.cmps):
            keep[k, i] = c.keep
            wb[k, i], ab[k, i] = effective_bits(c)
    return PolicyBatch(keep=keep, w_bits=wb, a_bits=ab)
