"""Continuous policy -> discrete CMP mapping (paper Eq. 1, 4, 8).

A *policy* is the per-layer list of continuous compression parameters in
[0,1] (Eq. 1). Actions from the agents are mapped:

  * pruning: Eq. 4 inverse mapping  d_v(r) = floor((1-r) * v) + 1
  * quantization: threshold selection (Eq. 8) with t_mix=0.5, t_int8=0.2,
    then Eq. 4 against the max mix bit width (6 — see quantization.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core import constraints
from repro.core.quantization import MAX_MIX_BITS
from repro.core.spec import LayerCMP, LayerSpec, effective_bits

T_MIX = 0.5
T_INT8 = 0.2


def d_inverse(r: float, v: int) -> int:
    """Paper Eq. 4: continuous ratio r in [0,1] -> discrete value in [1, v]."""
    return int(np.floor((1.0 - r) * v)) + 1 if v > 0 else 0


def scale_mix_action(a: float) -> float:
    """Paper Eq. 8 (with the min/max order fixed — the printed equation's
    clip bounds are transposed): r = clip((a - t_mix)/(1 - t_mix), 0, 1)."""
    return float(np.clip((a - T_MIX) / (1.0 - T_MIX), 0.0, 1.0))


def quant_cmp_from_actions(a_w: float, a_a: float,
                           max_bits: int = MAX_MIX_BITS) -> LayerCMP:
    """Threshold-based quant-mode selection (paper §Quantization details)."""
    if max(a_w, a_a) > T_MIX:
        # r is a *compression ratio*: r=0 -> max_bits, r=1 -> 1 bit (Eq. 4)
        r_w, r_a = scale_mix_action(a_w), scale_mix_action(a_a)
        return LayerCMP(keep=0, mode="MIX",
                        w_bits=min(d_inverse(r_w, max_bits), max_bits),
                        a_bits=min(d_inverse(r_a, max_bits), max_bits))
    if max(a_w, a_a) > T_INT8:
        return LayerCMP(keep=0, mode="INT8", w_bits=8, a_bits=8)
    return LayerCMP(keep=0, mode="FP32", w_bits=32, a_bits=32)


def prune_keep_from_action(spec: LayerSpec, a_p: float) -> int:
    """Action -> kept channel count (Eq. 4 with v = original count)."""
    if not spec.prunable or spec.prune_dim == 0:
        return spec.prune_dim
    return min(d_inverse(float(a_p), spec.prune_dim), spec.prune_dim)


def map_actions(spec: LayerSpec, actions: Sequence[float],
                methods: str) -> LayerCMP:
    """methods: "p" (prune), "q" (quant) or "pq" (joint)."""
    if methods == "p":
        cmp = LayerCMP(keep=prune_keep_from_action(spec, actions[0]))
    elif methods == "q":
        cmp = quant_cmp_from_actions(actions[0], actions[1])
        cmp.keep = spec.prune_dim
    elif methods == "pq":
        cmp = quant_cmp_from_actions(actions[1], actions[2])
        cmp.keep = prune_keep_from_action(spec, actions[0])
    else:
        raise ValueError(methods)
    return constraints.legalize(spec, cmp)


@dataclass
class Policy:
    """A complete compression policy for a model (one CMP per LayerSpec)."""
    cmps: List[LayerCMP] = field(default_factory=list)

    @staticmethod
    def reference(specs: Sequence[LayerSpec]) -> "Policy":
        """P_r — the initial no-compression policy."""
        return Policy([LayerCMP(keep=s.prune_dim) for s in specs])

    def macs_fraction(self, specs: Sequence[LayerSpec]) -> float:
        tot = sum(s.flops_per_token for s in specs) or 1.0
        acc = 0.0
        for s, c in zip(specs, self.cmps):
            f_out = (c.keep / s.prune_dim) if s.prune_dim else 1.0
            acc += s.flops_per_token * f_out
        return acc / tot

    def bops(self, specs: Sequence[LayerSpec]) -> float:
        """Bit operations: MACs * w_bits * a_bits (Baskin et al. 2021)."""
        acc = 0.0
        for s, c in zip(specs, self.cmps):
            f_out = (c.keep / s.prune_dim) if s.prune_dim else 1.0
            acc += s.flops_per_token / 2.0 * f_out * c.w_bits * c.a_bits
        return acc

    def n_actions(self, methods: str) -> int:
        return {"p": 1, "q": 2, "pq": 3}[methods]


@dataclass
class PolicyBatch:
    """K policies over the same LayerSpec list, as (K, L) arrays.

    ``keep`` holds kept counts; ``w_bits``/``a_bits`` hold *effective*
    bits (mode already resolved) — the form the vectorized latency
    oracle consumes.
    """
    keep: np.ndarray
    w_bits: np.ndarray
    a_bits: np.ndarray

    def __len__(self) -> int:
        return self.keep.shape[0]


def stack_policies(specs: Sequence[LayerSpec],
                   policies: Sequence[Policy]) -> PolicyBatch:
    """Pack K policies into the array form of ``PolicyBatch``."""
    K, L = len(policies), len(specs)
    keep = np.zeros((K, L), np.float64)
    wb = np.zeros((K, L), np.float64)
    ab = np.zeros((K, L), np.float64)
    for k, p in enumerate(policies):
        for i, c in enumerate(p.cmps):
            keep[k, i] = c.keep
            wb[k, i], ab[k, i] = effective_bits(c)
    return PolicyBatch(keep=keep, w_bits=wb, a_bits=ab)
