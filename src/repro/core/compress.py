"""Apply a compression policy to a model: LayerSpec enumeration per
architecture family, cspec building (quant bits + ℓ1 pruning masks), and
deployment-time weight slicing.

Two model adapters implement the ``CompressibleModel`` protocol used by the
search loop: ``CompressibleLM`` (any ArchConfig) and ``CompressibleResNet``
(the paper's own testbed family).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import pruning
from repro.core.policy import Policy, PolicyBatch
from repro.core.spec import LayerCMP, LayerSpec, effective_bits
from repro.models import blocks as B
from repro.models import model as M
from repro.models import resnet as R


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def _head_granularity(head_dim: int, lane: int = 128) -> int:
    return _lcm(lane, head_dim) // head_dim if head_dim else 1


# ===========================================================================
# LayerSpec enumeration for ArchConfig LMs
# ===========================================================================

def lm_layer_specs(cfg: ArchConfig) -> List[LayerSpec]:
    specs: List[LayerSpec] = []
    d = cfg.d_model
    if cfg.frontend != "audio_stub":
        specs.append(LayerSpec(
            name="embed", kind="embed", layer_idx=-1, in_dim=cfg.vocab_size,
            out_dim=d, quantizable=True, mix_supported=False,
            weight_elems=cfg.vocab_size * d, act_elems_per_token=1))
    for i, kind in enumerate(cfg.layer_kinds):
        if kind == "attn":
            H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            specs.append(LayerSpec(
                name=f"L{i}.attn_qkv", kind="attn_qkv", layer_idx=i,
                in_dim=d, out_dim=(H + 2 * KV) * hd,
                prunable=True, prune_dim=H,
                prune_granularity=_head_granularity(hd),
                flops_per_token=2.0 * d * (H + 2 * KV) * hd,
                weight_elems=d * (H + 2 * KV) * hd,
                act_elems_per_token=d,
                extra={"head_dim": hd, "kv_heads": KV}))
            specs.append(LayerSpec(
                name=f"L{i}.attn_out", kind="attn_out", layer_idx=i,
                in_dim=H * hd, out_dim=d, dep_group=f"L{i}.heads",
                flops_per_token=2.0 * H * hd * d,
                weight_elems=H * hd * d, act_elems_per_token=H * hd))
            if cfg.moe is not None:
                E, K, ff = cfg.moe.num_experts, cfg.moe.top_k, cfg.d_ff
                gated = 2
                specs.append(LayerSpec(
                    name=f"L{i}.moe_up", kind="moe_up", layer_idx=i,
                    in_dim=d, out_dim=ff, prunable=True, prune_dim=ff,
                    prune_granularity=128,
                    flops_per_token=2.0 * K * d * ff * gated,
                    weight_elems=E * d * ff * gated, act_elems_per_token=K * d,
                    extra={"experts": E, "top_k": K}))
                specs.append(LayerSpec(
                    name=f"L{i}.moe_down", kind="moe_down", layer_idx=i,
                    in_dim=ff, out_dim=d, dep_group=f"L{i}.moe_ff",
                    flops_per_token=2.0 * K * ff * d,
                    weight_elems=E * ff * d, act_elems_per_token=K * ff,
                    extra={"experts": E, "top_k": K}))
                if cfg.moe.dense_residual:
                    specs.append(LayerSpec(
                        name=f"L{i}.dense_up", kind="mlp_up", layer_idx=i,
                        in_dim=d, out_dim=ff, prunable=True, prune_dim=ff,
                        prune_granularity=128,
                        flops_per_token=2.0 * d * ff * gated,
                        weight_elems=d * ff * gated, act_elems_per_token=d,
                        extra={"dense_residual": True}))
                    specs.append(LayerSpec(
                        name=f"L{i}.dense_down", kind="mlp_down", layer_idx=i,
                        in_dim=ff, out_dim=d, dep_group=f"L{i}.dense_ff",
                        flops_per_token=2.0 * ff * d,
                        weight_elems=ff * d, act_elems_per_token=ff,
                        extra={"dense_residual": True}))
            else:
                ff = cfg.d_ff
                gated = 2 if cfg.mlp in ("swiglu", "geglu") else 1
                specs.append(LayerSpec(
                    name=f"L{i}.mlp_up", kind="mlp_up", layer_idx=i,
                    in_dim=d, out_dim=ff, prunable=True, prune_dim=ff,
                    prune_granularity=128,
                    flops_per_token=2.0 * d * ff * gated,
                    weight_elems=d * ff * gated, act_elems_per_token=d))
                specs.append(LayerSpec(
                    name=f"L{i}.mlp_down", kind="mlp_down", layer_idx=i,
                    in_dim=ff, out_dim=d, dep_group=f"L{i}.ff",
                    flops_per_token=2.0 * ff * d,
                    weight_elems=ff * d, act_elems_per_token=ff))
        elif kind == "ssm":
            d_inner, nheads, conv_dim = B.ssm_dims(cfg)
            d_proj = 2 * d_inner + 2 * cfg.ssm.d_state + nheads
            specs.append(LayerSpec(
                name=f"L{i}.ssm_in", kind="ssm_in", layer_idx=i,
                in_dim=d, out_dim=d_proj, prunable=True, prune_dim=nheads,
                prune_granularity=_head_granularity(cfg.ssm.head_dim),
                flops_per_token=2.0 * d * d_proj,
                weight_elems=d * d_proj, act_elems_per_token=d,
                extra={"head_dim": cfg.ssm.head_dim,
                       "d_state": cfg.ssm.d_state}))
            specs.append(LayerSpec(
                name=f"L{i}.ssm_out", kind="ssm_out", layer_idx=i,
                in_dim=d_inner, out_dim=d, dep_group=f"L{i}.ssm_heads",
                flops_per_token=2.0 * d_inner * d,
                weight_elems=d_inner * d, act_elems_per_token=d_inner))
        elif kind == "rglru":
            w = cfg.lru_width
            specs.append(LayerSpec(
                name=f"L{i}.rglru_in", kind="rglru_in", layer_idx=i,
                in_dim=d, out_dim=2 * w, prunable=True, prune_dim=w,
                prune_granularity=128,
                flops_per_token=2.0 * d * 2 * w,
                weight_elems=d * 2 * w, act_elems_per_token=d))
            specs.append(LayerSpec(
                name=f"L{i}.rglru_out", kind="rglru_out", layer_idx=i,
                in_dim=w, out_dim=d, dep_group=f"L{i}.lru",
                flops_per_token=2.0 * w * d,
                weight_elems=w * d, act_elems_per_token=w))
            ff = cfg.d_ff
            gated = 2 if cfg.mlp in ("swiglu", "geglu") else 1
            specs.append(LayerSpec(
                name=f"L{i}.mlp_up", kind="mlp_up", layer_idx=i,
                in_dim=d, out_dim=ff, prunable=True, prune_dim=ff,
                prune_granularity=128,
                flops_per_token=2.0 * d * ff * gated,
                weight_elems=d * ff * gated, act_elems_per_token=d))
            specs.append(LayerSpec(
                name=f"L{i}.mlp_down", kind="mlp_down", layer_idx=i,
                in_dim=ff, out_dim=d, dep_group=f"L{i}.ff",
                flops_per_token=2.0 * ff * d,
                weight_elems=ff * d, act_elems_per_token=ff))
    specs.append(LayerSpec(
        name="head", kind="head", layer_idx=cfg.num_layers,
        in_dim=d, out_dim=cfg.vocab_size, quantizable=True,
        mix_supported=False,
        flops_per_token=2.0 * d * cfg.vocab_size,
        weight_elems=d * cfg.vocab_size, act_elems_per_token=d))
    return specs


# ===========================================================================
# cspec building (quant bits arrays + ℓ1 masks) for LM models
# ===========================================================================

def _qs(cmp: Optional[LayerCMP]):
    """QS dict; missing CMP -> FP32 pass-through (keeps pytree structure
    constant across policies)."""
    w, a = effective_bits(cmp) if cmp is not None else (32, 32)
    return {"w_bits": jnp.int32(w), "a_bits": jnp.int32(a)}


def _layer_params(params, i: int, scanned: bool):
    blocks = params["blocks"]
    if scanned:
        return jax.tree.map(lambda x: x[i], blocks)
    return blocks[i]


def _unit_prune_scores(cfg: ArchConfig, p_l, kind: str,
                       dense: bool = False):
    """ℓ1 scores of one unit's prunable dim — the ONE place the
    per-kind weight/score-function choice lives (shared by the scalar
    cspec builder and the traced batch builder, which must prune
    identical channels)."""
    if kind == "attn_qkv":
        return pruning.head_scores(p_l["attn"]["wq"]["w"], cfg.num_heads)
    if kind == "moe_up":
        return pruning.l1_scores(
            [p_l["moe"]["w_up"], p_l["moe"]["w_gate"]], axis=-1)
    if kind == "mlp_up" and dense:
        return pruning.l1_scores(
            [p_l["moe"]["dense_w_up"], p_l["moe"]["dense_w_gate"]],
            axis=-1)
    if kind == "mlp_up":
        ws = [p_l["mlp"]["w_up"]["w"]]
        if "w_gate" in p_l["mlp"]:
            ws.append(p_l["mlp"]["w_gate"]["w"])
        return pruning.l1_scores(ws)
    if kind == "ssm_in":
        d_inner, nheads, _ = B.ssm_dims(cfg)
        wx = p_l["ssm"]["in_proj"][:, d_inner:2 * d_inner]
        return pruning.head_scores(wx, nheads)
    if kind == "rglru_in":
        return pruning.l1_scores([p_l["rglru"]["w_x"],
                                  p_l["rglru"]["w_y"]])
    return None


def build_lm_cspec(cfg: ArchConfig, params, policy: Policy,
                   specs: Sequence[LayerSpec]) -> dict:
    scanned = cfg.scan_layers and cfg.homogeneous
    by_layer: dict[int, dict[str, LayerCMP]] = {}
    embed_bits = head_bits = None
    for s, c in zip(specs, policy.cmps):
        if s.kind == "embed":
            embed_bits = jnp.int32(effective_bits(c)[0])
        elif s.kind == "head":
            head_bits = jnp.int32(effective_bits(c)[0])
        else:
            by_layer.setdefault(s.layer_idx, {})[s.kind] = c

    layer_cspecs = []
    for i, kind in enumerate(cfg.layer_kinds):
        p_l = _layer_params(params, i, scanned)
        cm = by_layer.get(i, {})
        cs: dict[str, Any] = {}
        if kind == "attn":
            cq, co = cm.get("attn_qkv"), cm.get("attn_out")
            head_mask = None
            if cq is not None and cq.keep < cfg.num_heads:
                scores = _unit_prune_scores(cfg, p_l, "attn_qkv")
                head_mask = pruning.keep_mask(scores, cq.keep)
            cs["attn"] = {"qkv": _qs(cq),
                          "o": _qs(co),
                          "head_mask": head_mask}
            if cfg.moe is not None:
                cu, cd = cm.get("moe_up"), cm.get("moe_down")
                ff_mask = None
                if cu is not None and cu.keep < cfg.d_ff:
                    scores = _unit_prune_scores(cfg, p_l, "moe_up")
                    ff_mask = pruning.keep_mask(scores, cu.keep)
                moe_cs = {"up": _qs(cu),
                          "down": _qs(cd),
                          "ff_mask": ff_mask,
                          "dense_up": None, "dense_down": None,
                          "dense_ff_mask": None}
                du, dd = cm.get("mlp_up"), cm.get("mlp_down")
                if cfg.moe.dense_residual:
                    dmask = None
                    if du is not None and du.keep < cfg.d_ff:
                        scores = _unit_prune_scores(cfg, p_l, "mlp_up",
                                                    dense=True)
                        dmask = pruning.keep_mask(scores, du.keep)
                    moe_cs["dense_up"] = _qs(du)
                    moe_cs["dense_down"] = _qs(dd)
                    moe_cs["dense_ff_mask"] = dmask
                cs["moe"] = moe_cs
            else:
                cu, cd = cm.get("mlp_up"), cm.get("mlp_down")
                ff_mask = None
                if cu is not None and cu.keep < cfg.d_ff:
                    scores = _unit_prune_scores(cfg, p_l, "mlp_up")
                    ff_mask = pruning.keep_mask(scores, cu.keep)
                cs["mlp"] = {"up": _qs(cu),
                             "down": _qs(cd),
                             "ff_mask": ff_mask}
        elif kind == "ssm":
            ci, co = cm.get("ssm_in"), cm.get("ssm_out")
            nheads = B.ssm_dims(cfg)[1]
            head_mask = None
            if ci is not None and ci.keep < nheads:
                scores = _unit_prune_scores(cfg, p_l, "ssm_in")
                head_mask = pruning.keep_mask(scores, ci.keep)
            cs["ssm"] = {"in": _qs(ci),
                         "out": _qs(co),
                         "head_mask": head_mask}
        elif kind == "rglru":
            ci, co = cm.get("rglru_in"), cm.get("rglru_out")
            wmask = None
            if ci is not None and ci.keep < cfg.lru_width:
                scores = _unit_prune_scores(cfg, p_l, "rglru_in")
                wmask = pruning.keep_mask(scores, ci.keep)
            cs["rglru"] = {"in": _qs(ci),
                           "out": _qs(co),
                           "width_mask": wmask}
            cu, cd = cm.get("mlp_up"), cm.get("mlp_down")
            ff_mask = None
            if cu is not None and cu.keep < cfg.d_ff:
                scores = _unit_prune_scores(cfg, p_l, "mlp_up")
                ff_mask = pruning.keep_mask(scores, cu.keep)
            cs["mlp"] = {"up": _qs(cu),
                         "down": _qs(cd),
                         "ff_mask": ff_mask}
        layer_cspecs.append(cs)

    if True:  # fill masks for BOTH paths: keeps the cspec pytree structure
        # identical across policies, so one jit compilation serves the
        # whole search (bits/masks are traced values, never shapes).
        def fill_masks(cs_list):
            keys_with_masks = {"attn": ("head_mask", cfg.num_heads),
                               "mlp": ("ff_mask", cfg.d_ff),
                               "moe": ("ff_mask", cfg.d_ff),
                               "ssm": ("head_mask",
                                       B.ssm_dims(cfg)[1] if cfg.ssm else 0),
                               "rglru": ("width_mask", cfg.lru_width)}
            for cs in cs_list:
                for part, (mk, dim) in keys_with_masks.items():
                    if part in cs and cs[part].get(mk) is None and dim:
                        cs[part][mk] = jnp.ones((dim,), jnp.float32)
                if "moe" in cs and cfg.moe and cfg.moe.dense_residual:
                    if cs["moe"].get("dense_ff_mask") is None:
                        cs["moe"]["dense_ff_mask"] = jnp.ones(
                            (cfg.d_ff,), jnp.float32)
            return cs_list

        layer_cspecs = fill_masks(layer_cspecs)
    if scanned:
        blocks_cs = jax.tree.map(lambda *xs: jnp.stack(xs), *layer_cspecs)
    else:
        blocks_cs = layer_cspecs

    out = {"blocks": blocks_cs}
    if embed_bits is not None:
        out["embed_bits"] = embed_bits
    if head_bits is not None:
        out["head_bits"] = head_bits
    return out


# ===========================================================================
# Traced cspec builders — (keep, w_bits, a_bits) arrays -> cspec pytree
# ===========================================================================
#
# build_lm_cspec above runs host-side Python per policy (pytree slicing,
# eager score/mask ops). The builders here move all of that into traced
# jax: prune scores are policy-independent, so they are computed ONCE,
# and the remaining work (bit scalars + rank-based masks) is a pure
# function of the per-unit (keep, w_bits, a_bits) arrays. vmapping the
# builder composed with accuracy gives batched policy evaluation as a
# single jit call — the batched episode engine's validation path.

def _lm_prune_scores(cfg: ArchConfig, params,
                     specs: Sequence[LayerSpec]) -> dict:
    """spec index -> ℓ1 scores of its prunable dim (same
    ``_unit_prune_scores`` selection as build_lm_cspec, evaluated
    eagerly for every prunable unit)."""
    scanned = cfg.scan_layers and cfg.homogeneous
    out: dict[int, jnp.ndarray] = {}
    for idx, s in enumerate(specs):
        if not (s.prunable and s.prune_dim):
            continue
        p_l = _layer_params(params, s.layer_idx, scanned)
        sc = _unit_prune_scores(cfg, p_l, s.kind,
                                dense=bool(s.extra.get("dense_residual")))
        if sc is not None:
            out[idx] = sc
    return out


def make_lm_cspec_builder(cfg: ArchConfig, params,
                          specs: Sequence[LayerSpec]):
    """Returns build(keep, w_bits, a_bits) -> cspec, fully traceable.

    The produced cspec matches build_lm_cspec structurally AND
    numerically for the same policy (masks use the same ℓ1 scores with
    the same tie-breaking), so one jit of accuracy∘build serves every
    policy, and vmap over the arrays batches K policies.
    """
    scanned = cfg.scan_layers and cfg.homogeneous
    scores = _lm_prune_scores(cfg, params, specs)
    pos: dict = {}
    for idx, s in enumerate(specs):
        if s.kind in ("embed", "head"):
            pos[s.kind] = idx
        else:
            pos[(s.layer_idx, s.kind)] = idx

    def build(keep, w_bits, a_bits):
        def qs(key):
            i = pos.get(key)
            if i is None:
                return {"w_bits": jnp.int32(32), "a_bits": jnp.int32(32)}
            return {"w_bits": w_bits[i].astype(jnp.int32),
                    "a_bits": a_bits[i].astype(jnp.int32)}

        def mask(key, dim):
            i = pos.get(key)
            if i is None or i not in scores:
                return jnp.ones((dim,), jnp.float32)
            return pruning.keep_mask_dynamic(scores[i], keep[i])

        layer_cspecs = []
        for i, kind in enumerate(cfg.layer_kinds):
            cs: dict[str, Any] = {}
            if kind == "attn":
                cs["attn"] = {"qkv": qs((i, "attn_qkv")),
                              "o": qs((i, "attn_out")),
                              "head_mask": mask((i, "attn_qkv"),
                                                cfg.num_heads)}
                if cfg.moe is not None:
                    moe_cs = {"up": qs((i, "moe_up")),
                              "down": qs((i, "moe_down")),
                              "ff_mask": mask((i, "moe_up"), cfg.d_ff),
                              "dense_up": None, "dense_down": None,
                              "dense_ff_mask": None}
                    if cfg.moe.dense_residual:
                        moe_cs["dense_up"] = qs((i, "mlp_up"))
                        moe_cs["dense_down"] = qs((i, "mlp_down"))
                        moe_cs["dense_ff_mask"] = mask((i, "mlp_up"),
                                                       cfg.d_ff)
                    cs["moe"] = moe_cs
                else:
                    cs["mlp"] = {"up": qs((i, "mlp_up")),
                                 "down": qs((i, "mlp_down")),
                                 "ff_mask": mask((i, "mlp_up"), cfg.d_ff)}
            elif kind == "ssm":
                nheads = B.ssm_dims(cfg)[1]
                cs["ssm"] = {"in": qs((i, "ssm_in")),
                             "out": qs((i, "ssm_out")),
                             "head_mask": mask((i, "ssm_in"), nheads)}
            elif kind == "rglru":
                cs["rglru"] = {"in": qs((i, "rglru_in")),
                               "out": qs((i, "rglru_out")),
                               "width_mask": mask((i, "rglru_in"),
                                                  cfg.lru_width)}
                cs["mlp"] = {"up": qs((i, "mlp_up")),
                             "down": qs((i, "mlp_down")),
                             "ff_mask": mask((i, "mlp_up"), cfg.d_ff)}
            layer_cspecs.append(cs)
        if scanned:
            blocks_cs = jax.tree.map(lambda *xs: jnp.stack(xs),
                                     *layer_cspecs)
        else:
            blocks_cs = layer_cspecs
        out = {"blocks": blocks_cs}
        if "embed" in pos:
            out["embed_bits"] = w_bits[pos["embed"]].astype(jnp.int32)
        if "head" in pos:
            out["head_bits"] = w_bits[pos["head"]].astype(jnp.int32)
        return out

    return build


def make_resnet_cspec_builder(cmodel: "CompressibleResNet"):
    """ResNet analogue of make_lm_cspec_builder."""
    specs = cmodel.specs
    scores: dict[int, jnp.ndarray] = {}
    conv_i = 0
    for idx, s in enumerate(specs):
        if s.kind == "conv":
            if s.prunable:
                scores[idx] = pruning.l1_scores(
                    [cmodel._conv_weight(conv_i)])
            conv_i += 1

    def build(keep, w_bits, a_bits):
        cspec = []
        for idx, s in enumerate(specs):
            entry: dict[str, Any] = {"qs": None, "mask": None}
            if s.quantizable:
                entry["qs"] = {"w_bits": w_bits[idx].astype(jnp.int32),
                               "a_bits": a_bits[idx].astype(jnp.int32)}
            if idx in scores:
                entry["mask"] = pruning.keep_mask_dynamic(scores[idx],
                                                          keep[idx])
            cspec.append(entry)
        return cspec

    return build


# ===========================================================================
# Model adapters (protocol used by the search / sensitivity analysis)
# ===========================================================================

def stack_cspecs(cspecs: Sequence[Any]):
    """Stack K cspec pytrees along a new leading axis.

    cspecs are policy-independent in structure (masks always
    materialized, bits always present — see build_lm_cspec), so K of
    them stack leaf-wise into one batch a single vmapped evaluation can
    consume.
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs), *cspecs)


class _BatchedAccuracyMixin:
    """Batched accuracy evaluation, shared by both adapters."""

    def cspec_builder(self):
        """The traced cspec builder for the CURRENT params, cached per
        params identity — ONE builder (and one eager prune-score pass)
        shared by the batched/fused validators (``accuracy_policy_fn``)
        and the fused sensitivity analysis (``core.sensitivity``)."""
        cached = getattr(self, "_builder_cache", None)
        if cached is None or cached[0] is not self.params:
            self._builder_cache = (self.params, self._make_cspec_builder())
        return self._builder_cache[1]

    def build_cspec_batch(self, policies: Sequence[Policy]):
        return stack_cspecs([self.build_cspec(p) for p in policies])

    def accuracy_batch(self, batch: dict, stacked_cspec) -> jnp.ndarray:
        """(K,) accuracies for K stacked cspecs — one vmap-of-jit call
        instead of K sequential jit dispatches."""
        return self._acc_batch_fn(batch)(stacked_cspec)

    def _acc_batch_fn(self, batch: dict):
        cached = getattr(self, "_acc_batch_cache", None)
        if cached is not None and cached[0] is batch \
                and cached[2] is self.params:
            return cached[1]
        fn = jax.jit(jax.vmap(lambda cs: self.accuracy(batch, cs)))
        self._acc_batch_cache = (batch, fn, self.params)
        return fn

    def accuracy_policy_fn(self, batch: dict):
        """The pure traced-cspec validator: (K, L) int32 keep/w_bits/
        a_bits arrays -> (K,) accuracies, un-jitted so callers can
        inline it into a larger traced program (the epoch-fused engine
        chains it inside its ``lax.scan`` body).

        ``accuracy_policy_batch`` jits exactly this function; both share
        one cache keyed on batch AND params identity — swapping in new
        weights (e.g. after a QAT retrain) must re-trace, since the
        traced builder bakes params and prune scores in as constants.
        """
        cached = getattr(self, "_acc_pb_cache", None)
        if cached is None or cached[0] is not batch \
                or cached[3] is not self.params:
            build = self.cspec_builder()
            fn = jax.vmap(
                lambda k, w, a: self.accuracy(batch, build(k, w, a)))
            self._acc_pb_cache = (batch, fn, jax.jit(fn), self.params)
            cached = self._acc_pb_cache
        return cached[1]

    def accuracy_policy_batch(self, batch: dict,
                              pbatch: "PolicyBatch") -> jnp.ndarray:
        """(K,) accuracies straight from PolicyBatch arrays.

        The traced cspec builder fuses into the vmapped accuracy, so
        the whole validation (mask building included) is ONE jit call —
        no per-policy host-side cspec construction at all.
        """
        self.accuracy_policy_fn(batch)        # (re)fill the shared cache
        return self._acc_pb_cache[2](jnp.asarray(pbatch.keep, jnp.int32),
                                     jnp.asarray(pbatch.w_bits, jnp.int32),
                                     jnp.asarray(pbatch.a_bits, jnp.int32))


@dataclass
class CompressibleLM(_BatchedAccuracyMixin):
    """Adapter: ArchConfig LM + params + data -> the search interface."""
    cfg: ArchConfig
    params: Any

    def __post_init__(self):
        self.specs = lm_layer_specs(self.cfg)

    def build_cspec(self, policy: Policy):
        return build_lm_cspec(self.cfg, self.params, policy, self.specs)

    def _make_cspec_builder(self):
        return make_lm_cspec_builder(self.cfg, self.params, self.specs)

    def logits(self, batch: dict, cspec=None):
        return M.forward(self.cfg, self.params, tokens=batch["tokens"],
                         cspec=cspec)

    def log_probs(self, batch: dict, cspec=None):
        return jax.nn.log_softmax(self.logits(batch, cspec), -1)

    def accuracy(self, batch: dict, cspec=None) -> jnp.ndarray:
        """Next-token top-1 accuracy."""
        lg = self.logits(batch, cspec)[:, :-1]
        tgt = batch["tokens"][:, 1:]
        return jnp.mean((jnp.argmax(lg, -1) == tgt).astype(jnp.float32))


@dataclass
class CompressibleResNet(_BatchedAccuracyMixin):
    cfg: R.ResNetConfig
    params: Any

    def __post_init__(self):
        self.specs = R.layer_specs(self.cfg)

    def build_cspec(self, policy: Policy):
        cspec = []
        conv_i = 0
        convs = list(R._iter_convs(self.cfg))
        for s, c in zip(self.specs, policy.cmps):
            entry: dict[str, Any] = {"qs": _qs(c) if s.quantizable else None,
                                     "mask": None}
            if s.kind == "conv":
                if s.prunable:
                    # always materialize a mask (ones when unpruned) so the
                    # cspec structure is policy-independent -> one jit cache
                    w = self._conv_weight(conv_i)
                    scores = pruning.l1_scores([w])
                    entry["mask"] = pruning.keep_mask(scores, c.keep)
                conv_i += 1
            cspec.append(entry)
        return cspec

    def _make_cspec_builder(self):
        return make_resnet_cspec_builder(self)

    def _conv_weight(self, idx: int):
        i = 0
        if idx == 0:
            return self.params["stem"]["w"]
        i = 1
        for blocks in self.params["stages"]:
            for blk in blocks:
                for key in ("conv1", "conv2", "skip"):
                    if key in blk:
                        if i == idx:
                            return blk[key]["w"]
                        i += 1
        raise IndexError(idx)

    def logits(self, batch: dict, cspec=None):
        return R.forward(self.cfg, self.params, batch["images"], cspec)

    def log_probs(self, batch: dict, cspec=None):
        return jax.nn.log_softmax(self.logits(batch, cspec), -1)

    def accuracy(self, batch: dict, cspec=None) -> jnp.ndarray:
        lg = self.logits(batch, cspec)
        return jnp.mean((jnp.argmax(lg, -1) == batch["labels"])
                        .astype(jnp.float32))


# ===========================================================================
# Deployment: materialize truly sliced weights (unrolled LMs / ResNet)
# ===========================================================================

def slice_lm_params(cfg: ArchConfig, params, cspec) -> Any:
    """Slice pruned channels out for deployment (unrolled models only).
    Returns a new params pytree with reduced shapes."""
    if cfg.scan_layers and cfg.homogeneous:
        raise ValueError("slice requires an unrolled model; set "
                         "scan_layers=False for deployment")
    new = {k: v for k, v in params.items() if k != "blocks"}
    new_blocks = []
    for i, (p_l, cs) in enumerate(zip(params["blocks"], cspec["blocks"])):
        p_l = jax.tree.map(lambda x: x, p_l)  # shallow copy
        kind = cfg.layer_kinds[i]
        if kind == "attn" and cs.get("attn", {}).get("head_mask") is not None:
            hm = cs["attn"]["head_mask"]
            idx = pruning.slice_indices(hm)
            hd = cfg.head_dim
            cols = np.concatenate([np.arange(h * hd, (h + 1) * hd)
                                   for h in idx])
            a = p_l["attn"]
            a["wq"]["w"] = a["wq"]["w"][:, cols]
            if "b" in a["wq"]:
                a["wq"]["b"] = a["wq"]["b"][cols]
            a["wo"]["w"] = a["wo"]["w"][cols, :]
        mlp_cs = cs.get("mlp")
        if mlp_cs is not None and mlp_cs.get("ff_mask") is not None:
            idx = pruning.slice_indices(mlp_cs["ff_mask"])
            m = p_l["mlp"]
            m["w_up"]["w"] = m["w_up"]["w"][:, idx]
            if "w_gate" in m:
                m["w_gate"]["w"] = m["w_gate"]["w"][:, idx]
            m["w_down"]["w"] = m["w_down"]["w"][idx, :]
        new_blocks.append(p_l)
    new["blocks"] = new_blocks
    return new
