"""DDPG (Lillicrap et al. 2015) in pure JAX — the paper's agent core.

Paper hyperparameters: actor/critic MLPs with hidden (400, 300); sigmoid-
bounded actions in [0,1]; Adam lr 1e-4 (actor) / 1e-3 (critic),
β1=0.9 β2=0.999; γ=0.99; batch 128; replay 2000; exploration via truncated
normal σ0=0.5, decay 0.95/episode; rewards in each sampled batch normalized
with a moving average; states standardized with running mean/var estimates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DDPGConfig:
    state_dim: int = 16
    action_dim: int = 1
    hidden: Tuple[int, int] = (400, 300)
    actor_lr: float = 1e-4
    critic_lr: float = 1e-3
    gamma: float = 0.99
    tau: float = 0.01                  # soft target update
    batch_size: int = 128
    buffer_size: int = 2000
    sigma0: float = 0.5
    sigma_decay: float = 0.95
    warmup_episodes: int = 10
    updates_per_episode: int = 32
    reward_ma_decay: float = 0.95      # moving-average reward normalizer


def _mlp_init(key, dims, final_scale=3e-3):
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        lim = final_scale if i == len(dims) - 2 else 1.0 / math.sqrt(a)
        params.append({
            "w": jax.random.uniform(k, (a, b), jnp.float32, -lim, lim),
            "b": jnp.zeros((b,), jnp.float32)})
    return params


def _mlp(params, x, final_act=None):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act else x


def actor_forward(params, state):
    return _mlp(params, state, jax.nn.sigmoid)   # actions in [0, 1]


def _actor_forward_np(params, x: np.ndarray) -> np.ndarray:
    """Host-side actor forward. The actor MLP is tiny, so during
    batched rollouts a numpy matmul chain beats the per-call XLA
    dispatch + device sync by an order of magnitude."""
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = np.maximum(x, 0.0)
    return 1.0 / (1.0 + np.exp(-x))


def critic_forward(params, state, action):
    x = jnp.concatenate([state, action], axis=-1)
    return _mlp(params, x)[..., 0]


# --- minimal Adam (self-contained; the training stack has its own) ---

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, st, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = st["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, st["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, st["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps),
                          params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


@dataclass
class RunningNorm:
    """Standardize states with running mean/var (paper §Proposed Agents)."""
    dim: int
    count: float = 1e-4
    mean: np.ndarray = None
    var: np.ndarray = None

    def __post_init__(self):
        if self.mean is None:
            self.mean = np.zeros(self.dim, np.float32)
        if self.var is None:
            self.var = np.ones(self.dim, np.float32)

    def update(self, x: np.ndarray):
        x = np.atleast_2d(x)
        bc, bm, bv = x.shape[0], x.mean(0), x.var(0)
        delta = bm - self.mean
        tot = self.count + bc
        self.mean = self.mean + delta * bc / tot
        m_a = self.var * self.count
        m_b = bv * bc
        self.var = (m_a + m_b + delta ** 2 * self.count * bc / tot) / tot
        self.count = tot

    def normalize(self, x: np.ndarray) -> np.ndarray:
        return (x - self.mean) / np.sqrt(self.var + 1e-8)


class DDPGAgent:
    """One agent = actor + critic (+ targets) + optimizers + exploration."""

    def __init__(self, cfg: DDPGConfig, seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        k1, k2, self.key = jax.random.split(key, 3)
        dims_a = (cfg.state_dim,) + cfg.hidden + (cfg.action_dim,)
        dims_c = (cfg.state_dim + cfg.action_dim,) + cfg.hidden + (1,)
        self.actor = _mlp_init(k1, dims_a)
        self.critic = _mlp_init(k2, dims_c)
        self.target_actor = jax.tree.map(jnp.copy, self.actor)
        self.target_critic = jax.tree.map(jnp.copy, self.critic)
        self.opt_a = adam_init(self.actor)
        self.opt_c = adam_init(self.critic)
        self.norm = RunningNorm(cfg.state_dim)
        self.reward_ma = 0.0
        self.reward_ma_init = False
        self.np_rng = np.random.default_rng(seed)
        self._update = jax.jit(self._update_impl)
        self._actor_host = None            # numpy actor copy for rollouts

    # ---------------- acting ----------------
    def act(self, state: np.ndarray, sigma: float,
            random: bool = False) -> np.ndarray:
        if random:
            return self.np_rng.uniform(0, 1, self.cfg.action_dim) \
                .astype(np.float32)
        s = self.norm.normalize(state.astype(np.float32))
        mu = np.asarray(actor_forward(self.actor, jnp.asarray(s)))
        if sigma > 0:
            # truncated normal on [0, 1] around mu (paper Eq. 7)
            for _ in range(16):
                a = self.np_rng.normal(mu, sigma)
                if np.all((a >= 0) & (a <= 1)):
                    return a.astype(np.float32)
            a = np.clip(self.np_rng.normal(mu, sigma), 0, 1)
            return a.astype(np.float32)
        return mu.astype(np.float32)

    def act_batch(self, states: np.ndarray, sigmas: np.ndarray,
                  random_mask: np.ndarray) -> np.ndarray:
        """Batched ``act``: one actor forward over K stacked states.

        ``sigmas`` and ``random_mask`` are per-row (episodes in a batch
        keep their own sigma-schedule position and warmup flag). Noise
        is the same truncated normal on [0, 1], rejection-sampled
        row-wise with the shared agent RNG.
        """
        states = np.atleast_2d(np.asarray(states, np.float32))
        K, A = states.shape[0], self.cfg.action_dim
        sigmas = np.broadcast_to(np.asarray(sigmas, np.float32), (K,))
        random_mask = np.broadcast_to(np.asarray(random_mask, bool), (K,))
        out = np.empty((K, A), np.float32)
        if random_mask.any():
            out[random_mask] = self.np_rng.uniform(
                0, 1, (int(random_mask.sum()), A)).astype(np.float32)
        det = ~random_mask
        if not det.any():
            return out
        s = self.norm.normalize(states[det])
        mu = _actor_forward_np(self._host_actor(), s).astype(np.float32)
        sig = sigmas[det][:, None]
        a = mu.copy()
        pending = sigmas[det] > 0
        for _ in range(16):
            if not pending.any():
                break
            rows = np.where(pending)[0]
            cand = self.np_rng.normal(mu[rows], sig[rows])
            ok = np.all((cand >= 0) & (cand <= 1), axis=1)
            a[rows[ok]] = cand[ok]
            pending[rows[ok]] = False
        if pending.any():
            rows = np.where(pending)[0]
            a[rows] = np.clip(self.np_rng.normal(mu[rows], sig[rows]), 0, 1)
        out[det] = a.astype(np.float32)
        return out

    def sigma_at(self, episode: int) -> float:
        e = max(0, episode - self.cfg.warmup_episodes)
        return self.cfg.sigma0 * (self.cfg.sigma_decay ** e)

    def _host_actor(self):
        """numpy copy of the actor params, refreshed after updates."""
        if self._actor_host is None:
            self._actor_host = [
                {k: np.asarray(v, np.float32) for k, v in layer.items()}
                for layer in self.actor]
        return self._actor_host

    # ---------------- learning ----------------
    def _update_impl(self, actor, critic, t_actor, t_critic, opt_a, opt_c,
                     batch):
        s, a, r, s2, done = batch
        cfg = self.cfg

        def critic_loss(cp):
            a2 = actor_forward(t_actor, s2)
            q_target = r + cfg.gamma * (1.0 - done) * critic_forward(
                t_critic, s2, a2)
            q = critic_forward(cp, s, a)
            return jnp.mean((q - jax.lax.stop_gradient(q_target)) ** 2)

        lc, gc = jax.value_and_grad(critic_loss)(critic)
        critic, opt_c = adam_step(critic, gc, opt_c, cfg.critic_lr)

        def actor_loss(ap):
            return -jnp.mean(critic_forward(critic, s, actor_forward(ap, s)))

        la, ga = jax.value_and_grad(actor_loss)(actor)
        actor, opt_a = adam_step(actor, ga, opt_a, cfg.actor_lr)

        t_actor = jax.tree.map(
            lambda t, p: (1 - cfg.tau) * t + cfg.tau * p, t_actor, actor)
        t_critic = jax.tree.map(
            lambda t, p: (1 - cfg.tau) * t + cfg.tau * p, t_critic, critic)
        return actor, critic, t_actor, t_critic, opt_a, opt_c, lc, la

    def update(self, replay) -> Tuple[float, float]:
        cfg = self.cfg
        if len(replay) < cfg.batch_size:
            return 0.0, 0.0
        s, a, r, s2, done = replay.sample(cfg.batch_size)
        # normalize rewards in the batch with a moving average (paper)
        batch_mean = float(np.mean(r))
        if not self.reward_ma_init:
            self.reward_ma = batch_mean
            self.reward_ma_init = True
        else:
            d = cfg.reward_ma_decay
            self.reward_ma = d * self.reward_ma + (1 - d) * batch_mean
        r = r - self.reward_ma
        s = self.norm.normalize(s)
        s2 = self.norm.normalize(s2)
        batch = tuple(jnp.asarray(x) for x in (s, a, r, s2, done))
        (self.actor, self.critic, self.target_actor, self.target_critic,
         self.opt_a, self.opt_c, lc, la) = self._update(
            self.actor, self.critic, self.target_actor, self.target_critic,
            self.opt_a, self.opt_c, batch)
        self._actor_host = None
        return float(lc), float(la)

    def observe_states(self, states: np.ndarray):
        self.norm.update(states)
