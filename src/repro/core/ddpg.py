"""DDPG (Lillicrap et al. 2015) in pure JAX — the paper's agent core.

Paper hyperparameters: actor/critic MLPs with hidden (400, 300); sigmoid-
bounded actions in [0,1]; Adam lr 1e-4 (actor) / 1e-3 (critic),
β1=0.9 β2=0.999; γ=0.99; batch 128; replay 2000; exploration via truncated
normal σ0=0.5, decay 0.95/episode; rewards in each sampled batch normalized
with a moving average; states standardized with running mean/var estimates.

Layout
------
The agent is a *functional* subsystem: all learnable/learning state lives
in an ``AgentState`` pytree (actor/critic/targets/Adam moments/running-norm
stats/reward moving average/PRNG key) manipulated by pure functions:

  * ``agent_init(cfg, key)``                 — build a fresh state;
  * ``agent_act(cfg, st, s, key, sigma)``    — pure jax acting (truncated-
    normal exploration), the traceable twin of the host rollout path;
  * ``update_step(cfg, st, batch)``          — one critic/actor/target
    update on an explicit batch, including the per-step reward-moving-
    average advance and state standardization (normalizer stats are
    *frozen* inside the step — they only move at rollout boundaries);
  * ``update_chunk(cfg, st, replay, n)``     — ``lax.scan`` of n update
    steps with in-scan uniform replay sampling: one jitted dispatch, one
    host sync for the losses, instead of n sample+dispatch round-trips;
  * ``population_update_chunk(cfg, sts, replays, n)`` — ``jit(vmap)`` of
    the chunk over a stacked population of P agent states + buffers, so
    p/q/pq agents (or one agent per hardware target) share every update
    dispatch.

``DDPGAgent`` remains as a thin compatibility shim over ``AgentState``:
``act``/``act_batch`` keep the fast host-numpy rollout forward,
``update(replay)`` keeps the original host-sampled scalar semantics, and
``update_chunk(replay, n)`` dispatches the fused scan. Host-authoritative
pieces (running norm, reward-MA between dispatches, numpy rollout RNG)
are synced into the pytree right before each fused dispatch.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.replay import DeviceReplayData, device_replay_sample

_mlp_ops = None         # lazy kernels.ops handle (kernel package must not
                        # load at agent-import; mirrors core.quantization)


@dataclass(frozen=True)
class DDPGConfig:
    state_dim: int = 16
    action_dim: int = 1
    hidden: Tuple[int, int] = (400, 300)
    actor_lr: float = 1e-4
    critic_lr: float = 1e-3
    gamma: float = 0.99
    tau: float = 0.01                  # soft target update
    batch_size: int = 128
    buffer_size: int = 2000
    sigma0: float = 0.5
    sigma_decay: float = 0.95
    warmup_episodes: int = 10
    updates_per_episode: int = 32
    reward_ma_decay: float = 0.95      # moving-average reward normalizer


def _mlp_init(key, dims, final_scale=3e-3):
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        lim = final_scale if i == len(dims) - 2 else 1.0 / math.sqrt(a)
        params.append({
            "w": jax.random.uniform(k, (a, b), jnp.float32, -lim, lim),
            "b": jnp.zeros((b,), jnp.float32)})
    return params


def _mlp_kernel_route(params, x, final_act) -> bool:
    """True when this MLP forward should run through the fused Pallas
    kernel (``kernels.ops.fused_mlp3``): the kernel implements exactly
    the paper's 3-layer trunk on a 2D batch with a linear or sigmoid
    head, and only a TPU backend compiles it to Mosaic — everywhere else
    the reference jnp chain stays the default. ``GALEN_MLP_KERNEL=1``
    forces the kernel (interpreted off-TPU, for parity tests);
    ``GALEN_MLP_KERNEL=0`` forces the reference path even on TPU. The
    route is resolved at trace time, mirroring ``GALEN_FQ_KERNEL``."""
    if len(params) != 3 or x.ndim != 2:
        return False
    if final_act is not None and final_act is not jax.nn.sigmoid:
        return False
    v = os.environ.get("GALEN_MLP_KERNEL")
    if v is not None:
        return v == "1"
    return jax.default_backend() == "tpu"


def _mlp(params, x, final_act=None):
    if _mlp_kernel_route(params, x, final_act):
        global _mlp_ops
        if _mlp_ops is None:
            from repro.kernels import ops
            _mlp_ops = ops
        final = "sigmoid" if final_act is jax.nn.sigmoid else "linear"
        return _mlp_ops.fused_mlp3(params, x, final=final)
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act else x


def actor_forward(params, state):
    return _mlp(params, state, jax.nn.sigmoid)   # actions in [0, 1]


def _actor_forward_np(params, x: np.ndarray) -> np.ndarray:
    """Host-side actor forward. The actor MLP is tiny, so during
    batched rollouts a numpy matmul chain beats the per-call XLA
    dispatch + device sync by an order of magnitude."""
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = np.maximum(x, 0.0)
    return 1.0 / (1.0 + np.exp(-x))


def critic_forward(params, state, action):
    x = jnp.concatenate([state, action], axis=-1)
    return _mlp(params, x)[..., 0]


# --- minimal Adam (self-contained; the training stack has its own) ---

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, st, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = st["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, st["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, st["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps),
                          params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def polyak_update(target, online, tau):
    """Soft-target update ``(1 - tau) * target + tau * online``. Routed
    like ``_mlp``: the flat single-pass Pallas kernel on TPU (or under
    ``GALEN_MLP_KERNEL=1``), the per-leaf tree map everywhere else."""
    v = os.environ.get("GALEN_MLP_KERNEL")
    use_kernel = v == "1" if v is not None \
        else jax.default_backend() == "tpu"
    if use_kernel:
        global _mlp_ops
        if _mlp_ops is None:
            from repro.kernels import ops
            _mlp_ops = ops
        return _mlp_ops.fused_polyak(target, online, tau)
    return jax.tree.map(lambda t, p: (1 - tau) * t + tau * p,
                        target, online)


@dataclass
class RunningNorm:
    """Standardize states with running mean/var (paper §Proposed Agents)."""
    dim: int
    count: float = 1e-4
    mean: np.ndarray = None
    var: np.ndarray = None

    def __post_init__(self):
        if self.mean is None:
            self.mean = np.zeros(self.dim, np.float32)
        if self.var is None:
            self.var = np.ones(self.dim, np.float32)

    def update(self, x: np.ndarray):
        x = np.atleast_2d(x)
        bc, bm, bv = x.shape[0], x.mean(0), x.var(0)
        delta = bm - self.mean
        tot = self.count + bc
        self.mean = self.mean + delta * bc / tot
        m_a = self.var * self.count
        m_b = bv * bc
        self.var = (m_a + m_b + delta ** 2 * self.count * bc / tot) / tot
        self.count = tot

    def normalize(self, x: np.ndarray) -> np.ndarray:
        return (x - self.mean) / np.sqrt(self.var + 1e-8)


# ===========================================================================
# Functional core
# ===========================================================================

class AgentState(NamedTuple):
    """Everything one DDPG agent learns or consumes while learning.

    A pure pytree: scans carry it, ``vmap`` stacks P of them into a
    population, and the host shim treats it as the single source of
    truth for parameters between dispatches.
    """
    actor: list
    critic: list
    target_actor: list
    target_critic: list
    opt_a: dict
    opt_c: dict
    norm_count: jnp.ndarray     # () f32   running-norm sample count
    norm_mean: jnp.ndarray      # (state_dim,) f32
    norm_var: jnp.ndarray       # (state_dim,) f32
    reward_ma: jnp.ndarray      # () f32   moving-average reward
    reward_ma_init: jnp.ndarray  # () f32  0 = uninitialized
    key: jnp.ndarray            # PRNG key (drives in-scan sampling)


def agent_init(cfg: DDPGConfig, key) -> AgentState:
    k1, k2, key = jax.random.split(key, 3)
    dims_a = (cfg.state_dim,) + cfg.hidden + (cfg.action_dim,)
    dims_c = (cfg.state_dim + cfg.action_dim,) + cfg.hidden + (1,)
    actor = _mlp_init(k1, dims_a)
    critic = _mlp_init(k2, dims_c)
    return AgentState(
        actor=actor, critic=critic,
        target_actor=jax.tree.map(jnp.copy, actor),
        target_critic=jax.tree.map(jnp.copy, critic),
        opt_a=adam_init(actor), opt_c=adam_init(critic),
        norm_count=jnp.asarray(1e-4, jnp.float32),
        norm_mean=jnp.zeros((cfg.state_dim,), jnp.float32),
        norm_var=jnp.ones((cfg.state_dim,), jnp.float32),
        reward_ma=jnp.zeros((), jnp.float32),
        reward_ma_init=jnp.zeros((), jnp.float32),
        key=key)


def agent_act(cfg: DDPGConfig, st: AgentState, s, key, sigma):
    """Pure acting: standardized state -> actor -> truncated normal.

    Mirrors the host rejection sampler: 16 candidate draws, first
    in-bounds one wins, else the first draw clipped to [0, 1].
    """
    s = (s - st.norm_mean) / jnp.sqrt(st.norm_var + 1e-8)
    mu = actor_forward(st.actor, s)
    cand = mu + sigma * jax.random.normal(key, (16,) + mu.shape, jnp.float32)
    ok = jnp.all((cand >= 0.0) & (cand <= 1.0), axis=-1)
    first = jnp.argmax(ok)
    noisy = jnp.where(jnp.any(ok), cand[first], jnp.clip(cand[0], 0.0, 1.0))
    return jnp.where(sigma > 0.0, noisy, mu)


def agent_act_batch(cfg: DDPGConfig, st: AgentState, states, key, sigmas,
                    warmup):
    """Pure batched acting for the fused rollout scan: K states -> K
    actions in one traceable block.

    Per-row semantics match the engines' host path: warmup rows draw
    uniform [0,1) actions; live rows run the standardized actor with
    per-row truncated-normal exploration (16-candidate rejection via
    ``agent_act``). All randomness comes from ``key`` — one split for
    the warmup uniforms, then one subkey per row — so host code (parity
    tests, the numpy reference) can replay the exact draws.
    """
    K = states.shape[0]
    k_uni, k_act = jax.random.split(key)
    uniform = jax.random.uniform(k_uni, (K, cfg.action_dim), jnp.float32)
    keys = jax.random.split(k_act, K)
    acted = jax.vmap(lambda s, k, sig: agent_act(cfg, st, s, k, sig))(
        states, keys, sigmas)
    return jnp.where(jnp.asarray(warmup)[:, None], uniform, acted)


def observe_states_pure(st: AgentState, states) -> AgentState:
    """Advance the running-norm stats from an (N, state_dim) block — the
    traced twin of ``RunningNorm.update`` (same parallel-variance
    formula, f32), so the epoch scan can move the normalizer at batch
    boundaries without the host."""
    x = jnp.asarray(states, jnp.float32)
    bc = jnp.asarray(x.shape[0], jnp.float32)
    bm, bv = x.mean(axis=0), x.var(axis=0)
    delta = bm - st.norm_mean
    tot = st.norm_count + bc
    mean = st.norm_mean + delta * bc / tot
    m_a = st.norm_var * st.norm_count
    m_b = bv * bc
    var = (m_a + m_b + delta ** 2 * st.norm_count * bc / tot) / tot
    return st._replace(norm_count=tot, norm_mean=mean, norm_var=var)


def ddpg_step(cfg: DDPGConfig, actor, critic, t_actor, t_critic,
              opt_a, opt_c, batch):
    """One critic + actor + soft-target update on a prepared batch
    (states already standardized, rewards already centered)."""
    s, a, r, s2, done = batch

    def critic_loss(cp):
        a2 = actor_forward(t_actor, s2)
        q_target = r + cfg.gamma * (1.0 - done) * critic_forward(
            t_critic, s2, a2)
        q = critic_forward(cp, s, a)
        return jnp.mean((q - jax.lax.stop_gradient(q_target)) ** 2)

    lc, gc = jax.value_and_grad(critic_loss)(critic)
    critic, opt_c = adam_step(critic, gc, opt_c, cfg.critic_lr)

    def actor_loss(ap):
        return -jnp.mean(critic_forward(critic, s, actor_forward(ap, s)))

    la, ga = jax.value_and_grad(actor_loss)(actor)
    actor, opt_a = adam_step(actor, ga, opt_a, cfg.actor_lr)

    t_actor = polyak_update(t_actor, actor, cfg.tau)
    t_critic = polyak_update(t_critic, critic, cfg.tau)
    return actor, critic, t_actor, t_critic, opt_a, opt_c, lc, la


def update_step(cfg: DDPGConfig, st: AgentState, batch):
    """One full scalar-semantics update on an explicit sampled batch:
    reward-MA advance -> reward centering -> state standardization with
    the snapshot norm stats -> ``ddpg_step``."""
    s, a, r, s2, done = batch
    batch_mean = jnp.mean(r)
    d = cfg.reward_ma_decay
    ma = jnp.where(st.reward_ma_init > 0.0,
                   d * st.reward_ma + (1.0 - d) * batch_mean, batch_mean)
    r = r - ma
    inv = 1.0 / jnp.sqrt(st.norm_var + 1e-8)
    s = (s - st.norm_mean) * inv
    s2 = (s2 - st.norm_mean) * inv
    actor, critic, t_actor, t_critic, opt_a, opt_c, lc, la = ddpg_step(
        cfg, st.actor, st.critic, st.target_actor, st.target_critic,
        st.opt_a, st.opt_c, (s, a, r, s2, done))
    st = st._replace(actor=actor, critic=critic, target_actor=t_actor,
                     target_critic=t_critic, opt_a=opt_a, opt_c=opt_c,
                     reward_ma=ma.astype(jnp.float32),
                     reward_ma_init=jnp.ones((), jnp.float32))
    return st, (lc, la)


def chunk_sample_keys(key, n: int):
    """The per-step sampling keys a chunk of n updates will consume,
    plus the advanced carry key. Exposed so parity tests can replay the
    exact batches a chunk draws."""
    carry, samp = jax.random.split(key)
    return carry, jax.random.split(samp, n)


# scan unroll for update chunks: 2 fuses adjacent steps enough to cut
# per-iteration overhead ~30% on CPU without the compile-time blowup of
# higher factors (measured: 6.6 -> 4.6 ms/update at 2, 4.3 at 8)
_SCAN_UNROLL = 2


def update_chunk(cfg: DDPGConfig, st: AgentState,
                 replay: DeviceReplayData, n: int):
    """n critic/actor/target updates as one ``lax.scan``: per-step
    uniform replay sampling, reward-MA advance, and parameter updates
    all stay on device; callers sync once for the (n,) loss arrays.

    The per-step in-scan gather fuses into the update step — measured
    faster than hoisting all n batch gathers out of the scan."""
    carry_key, keys = chunk_sample_keys(st.key, n)
    st = st._replace(key=carry_key)

    def step(carry, k):
        batch = device_replay_sample(replay, k, cfg.batch_size)
        return update_step(cfg, carry, batch)

    return jax.lax.scan(step, st, keys, unroll=min(_SCAN_UNROLL, n))


@partial(jax.jit, static_argnums=(0, 3))
def _update_chunk_jit(cfg, st, replay, n):
    return update_chunk(cfg, st, replay, n)


@partial(jax.jit, static_argnums=(0, 3))
def _population_update_chunk_jit(cfg, sts, replays, n):
    return jax.vmap(lambda s, r: update_chunk(cfg, s, r, n))(sts, replays)


def population_update_chunk_vmap(cfg: DDPGConfig, states: AgentState,
                                 replays: DeviceReplayData, n: int):
    """``jit(vmap(update_chunk))`` over P stacked agent states and
    buffers — the parity REFERENCE for the megabatched path below.

    ``states``/``replays`` are pytrees whose leaves carry a leading
    population axis (see ``tree_stack``)."""
    return _population_update_chunk_jit(cfg, states, replays, n)


# ===========================================================================
# Megabatched population updates (ISSUE 7 tentpole)
# ===========================================================================
#
# The vmap path above turns every per-member op into a (P, ...)-batched op,
# but leaves the autodiff-materialized residual traffic, the tree-form Adam
# (two extra full-tree passes for bias correction), separate Polyak passes,
# and non-donated carries in the program. The megabatched path below writes
# the SAME update step (bit-compatible to ~1e-7) with the population axis
# folded into every GEMM's batch dimension explicitly and the overhead
# structurally removed:
#
#   * merged forwards — the target-actor/target-critic/critic chains run as
#     (P·B)-row batched GEMMs via broadcasted ``jnp.matmul``/``einsum``;
#   * a hand-written backward: only the cotangents DDPG needs are formed
#     (no input-gradient for data tensors; the actor-loss first critic
#     layer is split ``[s, pi] @ W1 = s @ W1[:S] + pi @ W1[S:]`` so the
#     backward computes action-column input grads only);
#   * backward GEMMs in ``einsum`` layout (measured faster than the
#     swapaxes-matmul forms XLA autodiff emits on CPU);
#   * Adam with the bias correction folded into per-step scalars
#     (lr_t = lr·sqrt(1-b2^t)/(1-b1^t), eps_t = eps·sqrt(1-b2^t) — exact
#     rewrite, no mh/vh tree materialization) fused with the Polyak EMA
#     into ONE tree pass;
#   * an optional donated entry point so the carried (P, D) parameter /
#     moment buffers update in place.
#
# On MXU-class backends folding P into the GEMM batch axis is where the
# wall-clock win comes from; on the 1-core CI box the vmapped GEMMs already
# run at the machine's measured ~140 GF/s peak, so the gain there is the
# removed overhead only (see benchmarks/search_setup.py update_floor rows).


def _fused_adam_polyak(params, grads, st, target, lr, tau,
                       b1=0.9, b2=0.999, eps=1e-8):
    """Adam (folded bias correction) + Polyak target EMA in one tree
    pass over stacked (P, ...) leaves. ``st["t"]`` is (P,) int32.

    Exact rewrite of ``adam_step`` + the tau EMA: dividing m by (1-b1^t)
    and v by (1-b2^t) is folded into lr_t/eps_t so no bias-corrected
    tree is ever materialized."""
    t = st["t"] + 1
    tf = t.astype(jnp.float32)
    c1 = 1.0 - b1 ** tf
    c2 = 1.0 - b2 ** tf
    lr_t = lr * jnp.sqrt(c2) / c1        # (P,)
    eps_t = eps * jnp.sqrt(c2)           # (P,)

    def upd(p, m, v, g, tg):
        nd = (1,) * (p.ndim - 1)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        p2 = p - lr_t.reshape(-1, *nd) * m2 \
            / (jnp.sqrt(v2) + eps_t.reshape(-1, *nd))
        return (p2, m2, v2, (1 - tau) * tg + tau * p2)

    out = jax.tree.map(upd, params, st["m"], st["v"], grads, target)
    leaves, treedef = jax.tree.flatten(
        out, is_leaf=lambda x: isinstance(x, tuple))
    unf = lambda i: jax.tree.unflatten(treedef, [l[i] for l in leaves])
    return unf(0), {"m": unf(1), "v": unf(2), "t": t}, unf(3)


def _bmm(x, w):
    """(P, B, i) @ (P, i, o): the population axis folded into the GEMM
    batch dimension."""
    return jnp.matmul(x, w)


def _bwd_dw(h, dz):
    """Weight cotangent (P, B, i),(P, B, o) -> (P, i, o)."""
    return jnp.einsum("pbi,pbo->pio", h, dz)


def _bwd_dx(dz, w):
    """Input cotangent (P, B, o),(P, i, o) -> (P, B, i)."""
    return jnp.einsum("pbo,pio->pbi", dz, w)


def _mega_update_step(cfg: DDPGConfig, st: AgentState, batch):
    """One population update step with every GEMM P-megabatched and a
    hand-written backward. Semantics match ``update_step`` member-wise
    (same reward-MA advance, frozen-norm standardization, critic-then-
    actor Adam, Polyak) — the parity tests pin it at <= 1e-5."""
    s, a, r, s2, done = batch            # (P, B, ...) / (P, B)
    S = cfg.state_dim
    bias = lambda l: l["b"][:, None, :]

    batch_mean = jnp.mean(r, axis=1)     # (P,)
    d = cfg.reward_ma_decay
    ma = jnp.where(st.reward_ma_init > 0.0,
                   d * st.reward_ma + (1.0 - d) * batch_mean, batch_mean)
    r = r - ma[:, None]
    inv = 1.0 / jnp.sqrt(st.norm_var + 1e-8)
    s = (s - st.norm_mean[:, None, :]) * inv[:, None, :]
    s2 = (s2 - st.norm_mean[:, None, :]) * inv[:, None, :]

    TA, TC, CR, AC = st.target_actor, st.target_critic, st.critic, st.actor

    # ---- q_target through the target nets (forward only, no grads) ----
    x = jax.nn.relu(_bmm(s2, TA[0]["w"]) + bias(TA[0]))
    x = jax.nn.relu(_bmm(x, TA[1]["w"]) + bias(TA[1]))
    a2 = jax.nn.sigmoid(_bmm(x, TA[2]["w"]) + bias(TA[2]))
    x = jnp.concatenate([s2, a2], -1)
    x = jax.nn.relu(_bmm(x, TC[0]["w"]) + bias(TC[0]))
    x = jax.nn.relu(_bmm(x, TC[1]["w"]) + bias(TC[1]))
    q_next = (_bmm(x, TC[2]["w"]) + bias(TC[2]))[..., 0]
    q_target = r + cfg.gamma * (1.0 - done) * q_next       # (P, B)

    # ---- critic loss: forward + hand backward + fused Adam/Polyak ----
    xc = jnp.concatenate([s, a], -1)
    z1 = _bmm(xc, CR[0]["w"]) + bias(CR[0])
    h1 = jax.nn.relu(z1)
    z2 = _bmm(h1, CR[1]["w"]) + bias(CR[1])
    h2 = jax.nn.relu(z2)
    q = (_bmm(h2, CR[2]["w"]) + bias(CR[2]))[..., 0]
    e = q - q_target
    lc = jnp.mean(e * e, axis=1)                           # (P,)
    dz3 = ((2.0 / e.shape[1]) * e)[..., None]              # d lc / d q
    dW3 = _bwd_dw(h2, dz3)
    db3 = jnp.sum(dz3, axis=1)
    dz2 = _bwd_dx(dz3, CR[2]["w"]) * (z2 > 0)
    dW2 = _bwd_dw(h1, dz2)
    db2 = jnp.sum(dz2, axis=1)
    dz1 = _bwd_dx(dz2, CR[1]["w"]) * (z1 > 0)
    dW1 = _bwd_dw(xc, dz1)
    db1 = jnp.sum(dz1, axis=1)
    gc = [{"w": dW1, "b": db1}, {"w": dW2, "b": db2},
          {"w": dW3, "b": db3}]
    critic, opt_c, t_critic = _fused_adam_polyak(
        CR, gc, st.opt_c, st.target_critic, cfg.critic_lr, cfg.tau)

    # ---- actor loss against the UPDATED critic. The critic's first
    # layer is split [s, pi] @ W1 = s @ W1[:S] + pi @ W1[S:], so the
    # state half is a constant and the backward computes only the
    # action-column input grads (d pi) ----
    w1s = critic[0]["w"][:, :S, :]
    w1a = critic[0]["w"][:, S:, :]
    z1a = _bmm(s, AC[0]["w"]) + bias(AC[0])
    h1a = jax.nn.relu(z1a)
    z2a = _bmm(h1a, AC[1]["w"]) + bias(AC[1])
    h2a = jax.nn.relu(z2a)
    pi = jax.nn.sigmoid(_bmm(h2a, AC[2]["w"]) + bias(AC[2]))
    zq1 = _bmm(s, w1s) + _bmm(pi, w1a) + bias(critic[0])
    hq1 = jax.nn.relu(zq1)
    zq2 = _bmm(hq1, critic[1]["w"]) + bias(critic[1])
    hq2 = jax.nn.relu(zq2)
    qpi = (_bmm(hq2, critic[2]["w"]) + bias(critic[2]))[..., 0]
    la = -jnp.mean(qpi, axis=1)                            # (P,)
    B = qpi.shape[1]
    dz3q = jnp.full_like(hq2[..., :1], -1.0 / B)           # d la / d qpi
    dzq2 = _bwd_dx(dz3q, critic[2]["w"]) * (zq2 > 0)
    dzq1 = _bwd_dx(dzq2, critic[1]["w"]) * (zq1 > 0)
    dpi = _bwd_dx(dzq1, w1a)
    dz3a = dpi * pi * (1.0 - pi)
    dA3 = _bwd_dw(h2a, dz3a)
    db3a = jnp.sum(dz3a, axis=1)
    dz2a = _bwd_dx(dz3a, AC[2]["w"]) * (z2a > 0)
    dA2 = _bwd_dw(h1a, dz2a)
    db2a = jnp.sum(dz2a, axis=1)
    dz1a = _bwd_dx(dz2a, AC[1]["w"]) * (z1a > 0)
    dA1 = _bwd_dw(s, dz1a)
    db1a = jnp.sum(dz1a, axis=1)
    ga = [{"w": dA1, "b": db1a}, {"w": dA2, "b": db2a},
          {"w": dA3, "b": db3a}]
    actor, opt_a, t_actor = _fused_adam_polyak(
        AC, ga, st.opt_a, st.target_actor, cfg.actor_lr, cfg.tau)

    st = st._replace(actor=actor, critic=critic, target_actor=t_actor,
                     target_critic=t_critic, opt_a=opt_a, opt_c=opt_c,
                     reward_ma=ma.astype(jnp.float32),
                     reward_ma_init=jnp.ones_like(st.reward_ma_init))
    return st, (lc, la)


def _mega_chunk(cfg, states, replays, n):
    # per-member key streams replicate chunk_sample_keys / the in-scan
    # device_replay_sample draws of the vmap path exactly
    carry, keys = jax.vmap(lambda k: chunk_sample_keys(k, n))(states.key)
    states = states._replace(key=carry)
    keys = jnp.swapaxes(keys, 0, 1)                       # (n, P, key)

    def step(st, k):
        batch = jax.vmap(device_replay_sample, in_axes=(0, 0, None))(
            replays, k, cfg.batch_size)
        return _mega_update_step(cfg, st, batch)

    st, (lc, la) = jax.lax.scan(step, states, keys,
                                unroll=min(_SCAN_UNROLL, n))
    return st, (jnp.swapaxes(lc, 0, 1), jnp.swapaxes(la, 0, 1))


@partial(jax.jit, static_argnums=(0, 3))
def _population_update_chunk_mega_jit(cfg, states, replays, n):
    return _mega_chunk(cfg, states, replays, n)


@partial(jax.jit, static_argnums=(0, 3), donate_argnums=(1,))
def _population_update_chunk_mega_donate_jit(cfg, states, replays, n):
    return _mega_chunk(cfg, states, replays, n)


def population_update_chunk_megabatched(cfg: DDPGConfig,
                                        states: AgentState,
                                        replays: DeviceReplayData, n: int,
                                        donate: bool = False):
    """The megabatched population chunk: ONE jit execution for the whole
    population's ``n x P`` updates, parameters carried as (P, ...)
    stacked buffers, every GEMM batched over P.

    ``donate=True`` donates the stacked states so the parameter/moment
    buffers update in place — callers must not reuse ``states`` after
    the call (``PopulationSearch`` rebuilds them per dispatch)."""
    fn = _population_update_chunk_mega_donate_jit if donate \
        else _population_update_chunk_mega_jit
    return fn(cfg, states, replays, n)


def population_update_chunk(cfg: DDPGConfig, states: AgentState,
                            replays: DeviceReplayData, n: int,
                            donate: bool = False):
    """Route a population update chunk: the megabatched path by default,
    the ``jit(vmap(update_chunk))`` reference under
    ``GALEN_POP_UPDATE=vmap`` (or for network shapes the hand-written
    step does not cover — anything but the paper's 3-layer trunk).

    Both paths return the same structure: the advanced stacked states
    and per-member ``(P, n)`` critic/actor loss arrays, matching
    member-wise to <= 1e-5 (tests/test_update_floor.py)."""
    if os.environ.get("GALEN_POP_UPDATE") == "vmap" \
            or len(cfg.hidden) != 2:
        return population_update_chunk_vmap(cfg, states, replays, n)
    return population_update_chunk_megabatched(cfg, states, replays, n,
                                               donate=donate)


def tree_stack(trees, shardings=None):
    """Stack a list of identically-shaped pytrees along a new axis 0.

    ``shardings`` (a pytree of ``NamedSharding`` matching the STACKED
    result, e.g. ``distributed.sharding.population_shardings``) commits the
    stack to a device mesh along the member axis. jit follows committed
    input placements, so a subsequent donated population dispatch
    (``population_update_chunk(..., donate=True)`` or the fused epoch
    program) then partitions one member per device and updates the sharded
    buffers in place — the mesh-sharded fleet path costs no extra copies
    over the single-device one."""
    out = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out


def tree_index(tree, i: int):
    """Slice member i out of a stacked pytree."""
    return jax.tree.map(lambda x: x[i], tree)


# ===========================================================================
# Compatibility shim
# ===========================================================================

class DDPGAgent:
    """Thin stateful facade over the functional core.

    Keeps the original call sites — ``act`` / ``act_batch`` / ``update``
    / ``sigma_at`` / ``observe_states`` — while all parameters live in
    ``self.state`` (an ``AgentState``). The rollout path stays host-side
    numpy (fast for the tiny MLPs); the update path either takes the
    original one-host-sample-per-call route (``update``) or the fused
    scan (``update_chunk``).
    """

    def __init__(self, cfg: DDPGConfig, seed: int = 0):
        self.cfg = cfg
        self.state = agent_init(cfg, jax.random.PRNGKey(seed))
        self.norm = RunningNorm(cfg.state_dim)
        self._reward_ma_host = 0.0
        self._reward_ma_init_host = False
        self._ma_dirty = False       # True: state.reward_ma is newer
        self.np_rng = np.random.default_rng(seed)
        self._update = jax.jit(self._update_impl)
        self._actor_host = None            # numpy actor copy for rollouts

    # --- reward-MA facade: after a fused chunk the device value is
    # authoritative; pull it lazily so dispatching a chunk never blocks
    def _sync_ma(self):
        if self._ma_dirty:
            self._reward_ma_host = float(self.state.reward_ma)
            self._reward_ma_init_host = float(self.state.reward_ma_init) > 0
            self._ma_dirty = False

    @property
    def reward_ma(self):
        self._sync_ma()
        return self._reward_ma_host

    @reward_ma.setter
    def reward_ma(self, v):
        self._sync_ma()
        self._reward_ma_host = float(v)

    @property
    def reward_ma_init(self):
        self._sync_ma()
        return self._reward_ma_init_host

    @reward_ma_init.setter
    def reward_ma_init(self, v):
        self._sync_ma()
        self._reward_ma_init_host = bool(v)

    # --- state facade: legacy attribute names read/write the pytree ---
    @property
    def actor(self):
        return self.state.actor

    @actor.setter
    def actor(self, v):
        self.state = self.state._replace(actor=v)
        self._actor_host = None     # rollouts must see the new weights

    @property
    def critic(self):
        return self.state.critic

    @critic.setter
    def critic(self, v):
        self.state = self.state._replace(critic=v)

    @property
    def target_actor(self):
        return self.state.target_actor

    @target_actor.setter
    def target_actor(self, v):
        self.state = self.state._replace(target_actor=v)

    @property
    def target_critic(self):
        return self.state.target_critic

    @target_critic.setter
    def target_critic(self, v):
        self.state = self.state._replace(target_critic=v)

    @property
    def opt_a(self):
        return self.state.opt_a

    @opt_a.setter
    def opt_a(self, v):
        self.state = self.state._replace(opt_a=v)

    @property
    def opt_c(self):
        return self.state.opt_c

    @opt_c.setter
    def opt_c(self, v):
        self.state = self.state._replace(opt_c=v)

    # ---------------- acting ----------------
    def act(self, state: np.ndarray, sigma: float,
            random: bool = False) -> np.ndarray:
        if random:
            return self.np_rng.uniform(0, 1, self.cfg.action_dim) \
                .astype(np.float32)
        s = self.norm.normalize(state.astype(np.float32))
        mu = _actor_forward_np(self._host_actor(),
                               np.atleast_2d(s))[0].astype(np.float32)
        if sigma > 0:
            # truncated normal on [0, 1] around mu (paper Eq. 7)
            for _ in range(16):
                a = self.np_rng.normal(mu, sigma)
                if np.all((a >= 0) & (a <= 1)):
                    return a.astype(np.float32)
            a = np.clip(self.np_rng.normal(mu, sigma), 0, 1)
            return a.astype(np.float32)
        return mu.astype(np.float32)

    def act_batch(self, states: np.ndarray, sigmas: np.ndarray,
                  random_mask: np.ndarray) -> np.ndarray:
        """Batched ``act``: one actor forward over K stacked states.

        ``sigmas`` and ``random_mask`` are per-row (episodes in a batch
        keep their own sigma-schedule position and warmup flag). Noise
        is the same truncated normal on [0, 1], rejection-sampled
        row-wise with the shared agent RNG.
        """
        states = np.atleast_2d(np.asarray(states, np.float32))
        K, A = states.shape[0], self.cfg.action_dim
        sigmas = np.broadcast_to(np.asarray(sigmas, np.float32), (K,))
        random_mask = np.broadcast_to(np.asarray(random_mask, bool), (K,))
        out = np.empty((K, A), np.float32)
        if random_mask.any():
            out[random_mask] = self.np_rng.uniform(
                0, 1, (int(random_mask.sum()), A)).astype(np.float32)
        det = ~random_mask
        if not det.any():
            return out
        s = self.norm.normalize(states[det])
        mu = _actor_forward_np(self._host_actor(), s).astype(np.float32)
        sig = sigmas[det][:, None]
        a = mu.copy()
        pending = sigmas[det] > 0
        for _ in range(16):
            if not pending.any():
                break
            rows = np.where(pending)[0]
            cand = self.np_rng.normal(mu[rows], sig[rows])
            ok = np.all((cand >= 0) & (cand <= 1), axis=1)
            a[rows[ok]] = cand[ok]
            pending[rows[ok]] = False
        if pending.any():
            rows = np.where(pending)[0]
            a[rows] = np.clip(self.np_rng.normal(mu[rows], sig[rows]), 0, 1)
        out[det] = a.astype(np.float32)
        return out

    def sigma_at(self, episode: int) -> float:
        e = max(0, episode - self.cfg.warmup_episodes)
        return self.cfg.sigma0 * (self.cfg.sigma_decay ** e)

    def _host_actor(self):
        """numpy copy of the actor params, refreshed after updates."""
        if self._actor_host is None:
            self._actor_host = [
                {k: np.asarray(v, np.float32) for k, v in layer.items()}
                for layer in self.state.actor]
        return self._actor_host

    # ---------------- learning ----------------
    def _update_impl(self, actor, critic, t_actor, t_critic, opt_a, opt_c,
                     batch):
        return ddpg_step(self.cfg, actor, critic, t_actor, t_critic,
                         opt_a, opt_c, batch)

    def update(self, replay) -> Tuple[float, float]:
        """Original scalar path: one host replay sample, host reward-MA
        advance and normalization, one jit dispatch. Kept verbatim as
        the parity reference for ``update_chunk``."""
        cfg = self.cfg
        if len(replay) < cfg.batch_size:
            return 0.0, 0.0
        s, a, r, s2, done = replay.sample(cfg.batch_size)
        # normalize rewards in the batch with a moving average (paper)
        batch_mean = float(np.mean(r))
        if not self.reward_ma_init:
            self.reward_ma = batch_mean
            self.reward_ma_init = True
        else:
            d = cfg.reward_ma_decay
            self.reward_ma = d * self.reward_ma + (1 - d) * batch_mean
        r = r - self.reward_ma
        s = self.norm.normalize(s)
        s2 = self.norm.normalize(s2)
        batch = tuple(jnp.asarray(x, jnp.float32) for x in (s, a, r, s2,
                                                            done))
        (actor, critic, t_actor, t_critic, opt_a, opt_c, lc, la) = \
            self._update(self.state.actor, self.state.critic,
                         self.state.target_actor, self.state.target_critic,
                         self.state.opt_a, self.state.opt_c, batch)
        self.state = self.state._replace(
            actor=actor, critic=critic, target_actor=t_actor,
            target_critic=t_critic, opt_a=opt_a, opt_c=opt_c)
        self._actor_host = None
        return float(lc), float(la)

    def update_chunk(self, replay, n: int):
        """Fused path: n updates (sampling included) in one dispatch
        against a ``DeviceReplay``; returns the (n,) loss arrays.

        Does not block: the losses (and the adopted state) are lazy jax
        arrays, so rollout work can overlap the scan."""
        if n <= 0 or len(replay) < self.cfg.batch_size:
            return np.zeros(0, np.float32), np.zeros(0, np.float32)
        st, (lc, la) = _update_chunk_jit(self.cfg, self.state_for_dispatch(),
                                         replay.data, int(n))
        self.adopt_state(st)
        return lc, la

    def state_for_dispatch(self) -> AgentState:
        """Sync host-authoritative stats (running norm, reward-MA) into
        the pytree so a fused dispatch sees the same values the scalar
        path would."""
        st = self.state._replace(
            norm_count=jnp.asarray(self.norm.count, jnp.float32),
            norm_mean=jnp.asarray(self.norm.mean, jnp.float32),
            norm_var=jnp.asarray(self.norm.var, jnp.float32))
        if not self._ma_dirty:      # else the device value is current
            st = st._replace(
                reward_ma=jnp.asarray(self._reward_ma_host, jnp.float32),
                reward_ma_init=jnp.asarray(
                    1.0 if self._reward_ma_init_host else 0.0, jnp.float32))
        return st

    def adopt_state(self, st: AgentState):
        """Take a post-dispatch state as truth; the reward-MA is pulled
        back to the host lazily (first read), so adopting never forces
        a device sync. Invalidates the cached rollout actor."""
        self.state = st
        self._ma_dirty = True
        self._actor_host = None

    def observe_states(self, states: np.ndarray):
        self.norm.update(states)
