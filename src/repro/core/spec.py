"""Shared compression types — no deps beyond dataclasses/jnp.

``LayerSpec`` describes one compressible unit (a conv/linear or a fused
group like qkv) to the search: what can be pruned/quantized, the hardware
rounding granularity, and the cost-model inputs the latency oracle needs.

``LayerCMP`` is the *discrete* compression decision for one unit — the
output of mapping the agent's continuous actions (paper Eq. 4/8).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class LayerSpec:
    name: str                  # e.g. "blocks.3.mlp.up"
    kind: str                  # conv|attn_qkv|attn_out|mlp_up|mlp_down|
                               # moe_up|moe_down|ssm_in|ssm_out|
                               # rglru_in|rglru_out|embed|head
    layer_idx: int             # block index; -1 for embed/head
    in_dim: int
    out_dim: int
    # pruning
    prunable: bool = False
    prune_dim: int = 0         # size of the prunable dim (ff / heads / ch)
    prune_granularity: int = 1 # hardware rounding multiple
    dep_group: str = ""        # non-empty => pruning follows another unit
    # quantization
    quantizable: bool = True
    mix_supported: bool = True
    # cost model (per token, at full width)
    flops_per_token: float = 0.0
    weight_elems: int = 0
    act_elems_per_token: int = 0
    extra: dict = field(default_factory=dict, hash=False, compare=False)


@dataclass
class LayerCMP:
    """Discrete compression-method parameters for one unit."""
    keep: int                  # kept channels/heads on the prunable dim
    mode: str = "FP32"         # FP32|INT8|MIX
    w_bits: int = 32
    a_bits: int = 32

    @property
    def sparsity(self) -> float:
        return 0.0


def effective_bits(cmp: "LayerCMP") -> tuple[int, int]:
    if cmp.mode == "FP32":
        return 32, 32
    if cmp.mode == "INT8":
        return 8, 8
    return cmp.w_bits, cmp.a_bits
