"""The Galen search loop (paper Fig. 1/2): episodes of layer-wise policy
prediction, hardware-oracle validation, and DDPG optimization.

Three agents (paper §Proposed Agents) share this loop and differ only in
``methods``:  "p" (pruning), "q" (quantization), "pq" (joint).

How the episode engine works
----------------------------
``CompressionSearch.run_episode`` is the scalar reference path: walk the
actionable units in order, build the agent state (which probes the
analytic latency oracle under the partial policy), act, map the
continuous action to a legal CMP, then validate the finished policy
(one jitted accuracy eval + one oracle call) and push the transitions
with the shared episode reward.

``BatchedCompressionSearch`` runs K episodes as one batched rollout
with identical per-episode semantics (each episode keeps its own sigma
from the decay schedule, its own warmup flag, and the shared-episode-
reward transition scheme): ``build_state_batch`` + one vectorized
oracle call per step for the states, ``DDPGAgent.act_batch`` for the
actions, one ``jit(vmap(accuracy))`` + one batched oracle call for
validation, and a single bulk ring write for the K*T transitions.

Where the learning happens (PR 2: the functional agent core)
-----------------------------------------------------------
Both engines store transitions in a device-resident ``DeviceReplay``
(``core/replay.py``) and dispatch *all* of an episode batch's critic/
actor/target updates as ONE jitted ``lax.scan`` —
``DDPGAgent.update_chunk`` over the ``AgentState`` pytree
(``core/ddpg.py``). Replay sampling, reward moving-average centering,
state standardization, and the Adam/soft-target math all run inside the
scan; the only host sync per episode batch is the loss array. The
scalar engine fuses its ``updates_per_episode`` steps the same way, so
the two paths differ only in rollout batching.

``PopulationSearch`` stacks P member searches (p/q/pq agents, multiple
seeds, or one member per hardware target) and replaces their P separate
update dispatches with one ``jit(vmap(update_chunk))`` over the stacked
``AgentState``/replay pytrees. Members with different native action
dimensionalities share one population by padding ``action_dim`` to the
maximum (``map_actions`` consumes a prefix of the action vector, so
trailing entries are inert for single-method agents).

Semantic notes, both at batch granularity: critic/actor updates for the
K episodes of a batch run after the whole batch (same total update
count) rather than interleaved between episodes, and the state
normalizer's running stats advance once per batch, so episodes within a
batch act on the stats from the previous batch boundary. Within an
update chunk the normalizer snapshot is frozen and the reward moving
average advances per step — exactly the scalar ``DDPGAgent.update``
semantics, property-tested in ``tests/test_agent_core.py``.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core.ddpg import (DDPGAgent, DDPGConfig, population_update_chunk,
                             tree_index, tree_stack)
from repro.core.latency import (V5E, HardwareTarget, LatencyContext,
                                policy_latency, policy_latency_batch)
from repro.core.policy import Policy, map_actions, stack_policies
from repro.core.replay import DeviceReplay
from repro.core.reward import RewardConfig, compute_reward
from repro.core.sensitivity import SensitivityResult, run_sensitivity
from repro.core.spec import effective_bits
from repro.core.state import build_state, build_state_batch, state_dim


@dataclass(frozen=True)
class SearchConfig:
    methods: str = "pq"                # p | q | pq
    episodes: int = 120
    reward: RewardConfig = field(default_factory=RewardConfig)
    ddpg: DDPGConfig = None            # filled in __post_init__ of the search
    seed: int = 0
    window: int = 0                    # attention window for the oracle
    track_bops: bool = True


@dataclass
class EpisodeRecord:
    episode: int
    reward: float
    accuracy: float
    latency_s: float
    latency_ratio: float
    macs_frac: float
    bops: float
    sigma: float
    policy: Policy = field(repr=False, default=None)


@dataclass
class SearchResult:
    history: List[EpisodeRecord]
    best: EpisodeRecord
    ref_latency_s: float
    ref_accuracy: float

    def best_under_budget(self, tol: float = 0.05) -> Optional[EpisodeRecord]:
        c = None
        for r in self.history:
            if r.latency_ratio <= (1.0 + tol):
                if c is None or r.accuracy > c.accuracy:
                    c = r
        return c


def _actionable(spec, methods: str) -> bool:
    if methods == "p":
        return spec.prunable and spec.prune_dim > 0
    if methods == "q":
        return spec.quantizable
    return spec.quantizable or (spec.prunable and spec.prune_dim > 0)


class CompressionSearch:
    """Owns: the compressible model, the sensitivity table, the latency
    oracle context, the agent, and the episode loop."""

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None):
        self.cmodel = cmodel
        self.specs = cmodel.specs
        self.cfg = search_cfg
        self.hw = hw
        self.ctx = ctx
        self.val_batch = val_batch
        native = Policy([]).n_actions(search_cfg.methods)
        ddpg_cfg = search_cfg.ddpg or DDPGConfig(
            state_dim=state_dim(native), action_dim=native)
        # a provided action_dim larger than the method's native one pads
        # the action space (population members must share shapes); a
        # smaller one is corrected up to native
        a_dim = max(native, ddpg_cfg.action_dim)
        if (ddpg_cfg.state_dim, ddpg_cfg.action_dim) != (state_dim(a_dim),
                                                         a_dim):
            ddpg_cfg = DDPGConfig(**{**ddpg_cfg.__dict__,
                                     "state_dim": state_dim(a_dim),
                                     "action_dim": a_dim})
        self.agent = DDPGAgent(ddpg_cfg, seed=search_cfg.seed)
        self.replay = DeviceReplay(ddpg_cfg.buffer_size, ddpg_cfg.state_dim,
                                   a_dim, seed=search_cfg.seed)
        self.sens = sens if sens is not None else run_sensitivity(
            cmodel, calib_batch if calib_batch is not None else val_batch)
        self._jit_acc = jax.jit(lambda cs: cmodel.accuracy(val_batch, cs))
        self.ref_policy = Policy.reference(self.specs)
        self.ref_lat = policy_latency(self.specs, self.ref_policy, hw, ctx,
                                      search_cfg.window)
        self.ref_acc = float(self._jit_acc(
            cmodel.build_cspec(self.ref_policy)))
        self.steps = [i for i, s in enumerate(self.specs)
                      if _actionable(s, search_cfg.methods)]
        self._pending_updates = 0
        self._defer_updates = False     # PopulationSearch batches flushes

    # ------------------------------------------------------------------
    def _flush_updates(self):
        """Dispatch the accumulated update budget as one fused chunk."""
        n = self._pending_updates
        self._pending_updates = 0
        if n > 0 and len(self.replay) >= self.agent.cfg.batch_size:
            self.agent.update_chunk(self.replay, n)

    def _queue_updates(self, n: int):
        self._pending_updates += n
        if not self._defer_updates:
            self._flush_updates()

    # ------------------------------------------------------------------
    def run_episode(self, episode: int) -> EpisodeRecord:
        cfg = self.cfg
        warmup = episode < self.agent.cfg.warmup_episodes
        sigma = self.agent.sigma_at(episode)
        partial = copy.deepcopy(self.ref_policy)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros(a_dim, np.float32)
        states, actions = [], []
        for t in self.steps:
            s_vec = build_state(self.specs, t, partial, self.sens, prev_a,
                                self.hw, self.ctx, self.ref_lat, cfg.window)
            a = self.agent.act(s_vec, sigma, random=warmup)
            cmp = map_actions(self.specs[t], a, cfg.methods)
            # single-method agents preserve the other method's parameters
            # from the reference policy (supports the sequential scheme:
            # a frozen stage-1 policy as the starting point, paper App. A)
            prev = partial.cmps[t]
            if cfg.methods == "q":
                cmp.keep = prev.keep
            elif cfg.methods == "p":
                cmp.mode, cmp.w_bits, cmp.a_bits = (prev.mode, prev.w_bits,
                                                    prev.a_bits)
            partial.cmps[t] = cmp
            states.append(s_vec)
            actions.append(a)
            prev_a = a
        policy = partial

        cspec = self.cmodel.build_cspec(policy)
        acc = float(self._jit_acc(cspec))
        lat = policy_latency(self.specs, policy, self.hw, self.ctx,
                             cfg.window)
        reward = compute_reward(cfg.reward, acc, lat.total_s,
                                self.ref_lat.total_s)
        # push transitions — one shared episode reward (paper §Schema),
        # one bulk ring write for the whole chain
        T = len(states)
        st_arr = np.stack(states)
        self.agent.observe_states(st_arr)
        nxt = np.concatenate([st_arr[1:], st_arr[-1:]])
        done = np.zeros(T, np.float32)
        done[-1] = 1.0
        self.replay.push_batch(st_arr, np.stack(actions),
                               np.full(T, reward, np.float32), nxt, done)
        if not warmup:
            self._queue_updates(self.agent.cfg.updates_per_episode)

        ratio = lat.total_s / (cfg.reward.target_ratio *
                               self.ref_lat.total_s)
        return EpisodeRecord(
            episode=episode, reward=reward, accuracy=acc,
            latency_s=lat.total_s, latency_ratio=ratio,
            macs_frac=policy.macs_fraction(self.specs),
            bops=policy.bops(self.specs) if cfg.track_bops else 0.0,
            sigma=sigma, policy=policy)

    # chunking hooks: the scalar engine advances one episode at a time;
    # BatchedCompressionSearch overrides these to roll K per call
    def _chunk_size(self) -> int:
        return 1

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return [self.run_episode(first_episode)]

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> SearchResult:
        n = episodes or self.cfg.episodes
        history: List[EpisodeRecord] = []
        best = None
        e = 0
        while e < n:
            k = min(self._chunk_size(), n - e)
            for rec in self._run_chunk(e, k):
                history.append(rec)
                if best is None or rec.reward > best.reward:
                    best = rec
                if verbose and (rec.episode % 10 == 0
                                or rec.episode == n - 1):
                    print(f"  ep {rec.episode:4d} reward={rec.reward:+.4f} "
                          f"acc={rec.accuracy:.3f} "
                          f"lat_ratio={rec.latency_ratio:.3f} "
                          f"sigma={rec.sigma:.3f}")
            e += k
        return SearchResult(history=history, best=best,
                            ref_latency_s=self.ref_lat.total_s,
                            ref_accuracy=self.ref_acc)


class BatchedCompressionSearch(CompressionSearch):
    """K episodes per rollout; see the module docstring for the engine.

    Per-episode semantics (sigma schedule, warmup, shared episode
    reward, legality constraints) match ``CompressionSearch``; only the
    dispatch is amortized, so episode throughput scales with K.
    """

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, batch_size: int = 8):
        super().__init__(cmodel, val_batch, search_cfg, ctx, hw=hw,
                         sens=sens, calib_batch=calib_batch)
        self.batch_size = max(1, batch_size)

    # ------------------------------------------------------------------
    def run_episode_batch(self, first_episode: int,
                          k: int) -> List[EpisodeRecord]:
        cfg = self.cfg
        eps = list(range(first_episode, first_episode + k))
        warmup = np.asarray(
            [e < self.agent.cfg.warmup_episodes for e in eps])
        sigmas = np.asarray([self.agent.sigma_at(e) for e in eps],
                            np.float32)
        partials = [copy.deepcopy(self.ref_policy) for _ in eps]
        # (K, L) policy arrays, updated in place as units are decided
        pb = stack_policies(self.specs, partials)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros((k, a_dim), np.float32)
        step_states, step_actions = [], []
        for t in self.steps:
            cur = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                       cfg.window)
            S = build_state_batch(self.specs, t, cur, self.sens, prev_a,
                                  self.ref_lat)
            A = self.agent.act_batch(S, sigmas, warmup)
            for j in range(k):
                cmp = map_actions(self.specs[t], A[j], cfg.methods)
                prev = partials[j].cmps[t]
                if cfg.methods == "q":
                    cmp.keep = prev.keep
                elif cfg.methods == "p":
                    cmp.mode, cmp.w_bits, cmp.a_bits = (
                        prev.mode, prev.w_bits, prev.a_bits)
                partials[j].cmps[t] = cmp
                pb.keep[j, t] = cmp.keep
                pb.w_bits[j, t], pb.a_bits[j, t] = effective_bits(cmp)
            step_states.append(S)
            step_actions.append(A)
            prev_a = A

        # --- batched validation: one fused cspec+accuracy jit call and
        # one vectorized oracle call for the whole batch
        accs = np.asarray(
            self.cmodel.accuracy_policy_batch(self.val_batch, pb))
        lats = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                    cfg.window).total_s
        rewards = np.asarray([
            compute_reward(cfg.reward, float(accs[j]), float(lats[j]),
                           self.ref_lat.total_s) for j in range(k)])

        # --- transitions: (T, K, ·) -> per-episode chains, one bulk push
        T = len(self.steps)
        states = np.stack(step_states)            # (T, K, state_dim)
        actions = np.stack(step_actions)          # (T, K, a_dim)
        self.agent.observe_states(states.reshape(T * k, -1))
        nxt = np.concatenate([states[1:], states[-1:]])
        done = np.zeros((T, k), np.float32)
        done[-1] = 1.0
        order = lambda x: x.swapaxes(0, 1).reshape(T * k, *x.shape[2:])
        self.replay.push_batch(
            order(states), order(actions),
            np.repeat(rewards, T).astype(np.float32),
            order(nxt), order(done))
        n_live = int((~warmup).sum())
        self._queue_updates(self.agent.cfg.updates_per_episode * n_live)

        records = []
        for j, e in enumerate(eps):
            pol = partials[j]
            ratio = float(lats[j]) / (cfg.reward.target_ratio *
                                      self.ref_lat.total_s)
            records.append(EpisodeRecord(
                episode=e, reward=float(rewards[j]),
                accuracy=float(accs[j]), latency_s=float(lats[j]),
                latency_ratio=ratio,
                macs_frac=pol.macs_fraction(self.specs),
                bops=pol.bops(self.specs) if cfg.track_bops else 0.0,
                sigma=float(sigmas[j]), policy=pol))
        return records

    def _chunk_size(self) -> int:
        return self.batch_size

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return self.run_episode_batch(first_episode, k)


class PopulationSearch:
    """P member searches whose agents share every update dispatch.

    This is the paper's actual workload shape: the p/q/pq agents (and,
    for hardware-specific policies, one member per target) search
    concurrently. Members roll out independently (each already batched
    over K episodes), but their per-chunk update budgets are dispatched
    as ONE ``jit(vmap(update_chunk))`` over the stacked ``AgentState``
    and ``DeviceReplay`` pytrees — P× fewer dispatches on the dominant
    cost of the loop.

    Requirements: members must share one ``DDPGConfig`` (pad
    ``action_dim`` to the population maximum for mixed-method
    populations; see the module docstring) and one chunk size. Members
    whose pending budgets diverge (e.g. different warmup positions)
    fall back to per-member fused flushes for that chunk.
    """

    def __init__(self, members: Sequence[CompressionSearch]):
        if not members:
            raise ValueError("PopulationSearch needs at least one member")
        self.members = list(members)
        cfg0 = self.members[0].agent.cfg
        for m in self.members[1:]:
            if m.agent.cfg != cfg0:
                raise ValueError(
                    "population members must share a DDPGConfig (pad "
                    f"action_dim): {m.agent.cfg} != {cfg0}")
        if len({m._chunk_size() for m in self.members}) != 1:
            raise ValueError("population members must share a chunk size")

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> List[SearchResult]:
        """Run all members for the same episode count; returns one
        ``SearchResult`` per member, aligned with ``self.members``."""
        n = episodes or min(m.cfg.episodes for m in self.members)
        histories = [[] for _ in self.members]
        bests = [None for _ in self.members]
        saved = [m._defer_updates for m in self.members]
        try:
            for m in self.members:
                m._defer_updates = True
            e = 0
            while e < n:
                k = min(self.members[0]._chunk_size(), n - e)
                for i, m in enumerate(self.members):
                    for rec in m._run_chunk(e, k):
                        histories[i].append(rec)
                        if bests[i] is None or rec.reward > bests[i].reward:
                            bests[i] = rec
                self._dispatch_updates()
                if verbose:
                    last = e + k - 1
                    row = " ".join(
                        f"{m.cfg.methods}:{histories[i][-1].reward:+.3f}"
                        for i, m in enumerate(self.members))
                    print(f"  ep {last:4d} rewards [{row}]")
                e += k
        finally:
            for m, flag in zip(self.members, saved):
                m._defer_updates = flag
        return [SearchResult(history=histories[i], best=bests[i],
                             ref_latency_s=m.ref_lat.total_s,
                             ref_accuracy=m.ref_acc)
                for i, m in enumerate(self.members)]

    def _dispatch_updates(self):
        """One vmapped chunk for the whole population when the members'
        budgets agree; per-member fused flushes otherwise."""
        ns = [m._pending_updates for m in self.members]
        ready = all(len(m.replay) >= m.agent.cfg.batch_size
                    for m in self.members)
        if len(set(ns)) == 1 and ns[0] > 0 and ready:
            n = ns[0]
            states = tree_stack(
                [m.agent.state_for_dispatch() for m in self.members])
            datas = tree_stack([m.replay.data for m in self.members])
            new_states, _losses = population_update_chunk(
                self.members[0].agent.cfg, states, datas, n)
            for i, m in enumerate(self.members):
                m.agent.adopt_state(tree_index(new_states, i))
                m._pending_updates = 0
        else:
            for m in self.members:
                m._flush_updates()
