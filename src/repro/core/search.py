"""The Galen search loop (paper Fig. 1/2): episodes of layer-wise policy
prediction, hardware-oracle validation, and DDPG optimization.

Three agents (paper §Proposed Agents) share this loop and differ only in
``methods``:  "p" (pruning), "q" (quantization), "pq" (joint).
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import numpy as np

from repro.core.ddpg import DDPGAgent, DDPGConfig
from repro.core.latency import (V5E, HardwareTarget, LatencyContext,
                                policy_latency)
from repro.core.policy import Policy, map_actions
from repro.core.replay import ReplayBuffer
from repro.core.reward import RewardConfig, compute_reward
from repro.core.sensitivity import SensitivityResult, run_sensitivity
from repro.core.state import build_state, state_dim


@dataclass(frozen=True)
class SearchConfig:
    methods: str = "pq"                # p | q | pq
    episodes: int = 120
    reward: RewardConfig = RewardConfig()
    ddpg: DDPGConfig = None            # filled in __post_init__ of the search
    seed: int = 0
    window: int = 0                    # attention window for the oracle
    track_bops: bool = True


@dataclass
class EpisodeRecord:
    episode: int
    reward: float
    accuracy: float
    latency_s: float
    latency_ratio: float
    macs_frac: float
    bops: float
    sigma: float
    policy: Policy = field(repr=False, default=None)


@dataclass
class SearchResult:
    history: List[EpisodeRecord]
    best: EpisodeRecord
    ref_latency_s: float
    ref_accuracy: float

    def best_under_budget(self, tol: float = 0.05) -> Optional[EpisodeRecord]:
        c = None
        for r in self.history:
            if r.latency_ratio <= (1.0 + tol):
                if c is None or r.accuracy > c.accuracy:
                    c = r
        return c


def _actionable(spec, methods: str) -> bool:
    if methods == "p":
        return spec.prunable and spec.prune_dim > 0
    if methods == "q":
        return spec.quantizable
    return spec.quantizable or (spec.prunable and spec.prune_dim > 0)


class CompressionSearch:
    """Owns: the compressible model, the sensitivity table, the latency
    oracle context, the agent, and the episode loop."""

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None):
        self.cmodel = cmodel
        self.specs = cmodel.specs
        self.cfg = search_cfg
        self.hw = hw
        self.ctx = ctx
        self.val_batch = val_batch
        a_dim = Policy([]).n_actions(search_cfg.methods)
        ddpg_cfg = search_cfg.ddpg or DDPGConfig(
            state_dim=state_dim(a_dim), action_dim=a_dim)
        if ddpg_cfg.state_dim != state_dim(a_dim):
            ddpg_cfg = DDPGConfig(**{**ddpg_cfg.__dict__,
                                     "state_dim": state_dim(a_dim),
                                     "action_dim": a_dim})
        self.agent = DDPGAgent(ddpg_cfg, seed=search_cfg.seed)
        self.replay = ReplayBuffer(ddpg_cfg.buffer_size, ddpg_cfg.state_dim,
                                   a_dim, seed=search_cfg.seed)
        self.sens = sens if sens is not None else run_sensitivity(
            cmodel, calib_batch if calib_batch is not None else val_batch)
        self._jit_acc = jax.jit(lambda cs: cmodel.accuracy(val_batch, cs))
        self.ref_policy = Policy.reference(self.specs)
        self.ref_lat = policy_latency(self.specs, self.ref_policy, hw, ctx,
                                      search_cfg.window)
        self.ref_acc = float(self._jit_acc(
            cmodel.build_cspec(self.ref_policy)))
        self.steps = [i for i, s in enumerate(self.specs)
                      if _actionable(s, search_cfg.methods)]

    # ------------------------------------------------------------------
    def run_episode(self, episode: int) -> EpisodeRecord:
        cfg = self.cfg
        warmup = episode < self.agent.cfg.warmup_episodes
        sigma = self.agent.sigma_at(episode)
        partial = copy.deepcopy(self.ref_policy)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros(a_dim, np.float32)
        states, actions = [], []
        for t in self.steps:
            s_vec = build_state(self.specs, t, partial, self.sens, prev_a,
                                self.hw, self.ctx, self.ref_lat, cfg.window)
            a = self.agent.act(s_vec, sigma, random=warmup)
            cmp = map_actions(self.specs[t], a, cfg.methods)
            # single-method agents preserve the other method's parameters
            # from the reference policy (supports the sequential scheme:
            # a frozen stage-1 policy as the starting point, paper App. A)
            prev = partial.cmps[t]
            if cfg.methods == "q":
                cmp.keep = prev.keep
            elif cfg.methods == "p":
                cmp.mode, cmp.w_bits, cmp.a_bits = (prev.mode, prev.w_bits,
                                                    prev.a_bits)
            partial.cmps[t] = cmp
            states.append(s_vec)
            actions.append(a)
            prev_a = a
        policy = partial

        cspec = self.cmodel.build_cspec(policy)
        acc = float(self._jit_acc(cspec))
        lat = policy_latency(self.specs, policy, self.hw, self.ctx,
                             cfg.window)
        reward = compute_reward(cfg.reward, acc, lat.total_s,
                                self.ref_lat.total_s)
        # push transitions — one shared episode reward (paper §Schema)
        self.agent.observe_states(np.stack(states))
        for i in range(len(states)):
            s_next = states[i + 1] if i + 1 < len(states) else states[i]
            done = i + 1 == len(states)
            self.replay.push(states[i], actions[i], reward, s_next, done)
        if not warmup:
            for _ in range(self.agent.cfg.updates_per_episode):
                self.agent.update(self.replay)

        ratio = lat.total_s / (cfg.reward.target_ratio *
                               self.ref_lat.total_s)
        return EpisodeRecord(
            episode=episode, reward=reward, accuracy=acc,
            latency_s=lat.total_s, latency_ratio=ratio,
            macs_frac=policy.macs_fraction(self.specs),
            bops=policy.bops(self.specs) if cfg.track_bops else 0.0,
            sigma=sigma, policy=policy)

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> SearchResult:
        n = episodes or self.cfg.episodes
        history: List[EpisodeRecord] = []
        best = None
        for e in range(n):
            rec = self.run_episode(e)
            history.append(rec)
            if best is None or rec.reward > best.reward:
                best = rec
            if verbose and (e % 10 == 0 or e == n - 1):
                print(f"  ep {e:4d} reward={rec.reward:+.4f} "
                      f"acc={rec.accuracy:.3f} lat_ratio={rec.latency_ratio:.3f} "
                      f"sigma={rec.sigma:.3f}")
        return SearchResult(history=history, best=best,
                            ref_latency_s=self.ref_lat.total_s,
                            ref_accuracy=self.ref_acc)
