"""The Galen search loop (paper Fig. 1/2): episodes of layer-wise policy
prediction, hardware-oracle validation, and DDPG optimization.

Three agents (paper §Proposed Agents) share this loop and differ only in
``methods``:  "p" (pruning), "q" (quantization), "pq" (joint).

How the episode engine works
----------------------------
``CompressionSearch.run_episode`` is the scalar reference path: walk the
actionable units in order, build the agent state (which probes the
analytic latency oracle under the partial policy), act, map the
continuous action to a legal CMP, then validate the finished policy
(one jitted accuracy eval + one oracle call) and push the transitions
with the shared episode reward.

``BatchedCompressionSearch`` runs K episodes as one batched rollout
with identical per-episode semantics (each episode keeps its own sigma
from the decay schedule, its own warmup flag, and the shared-episode-
reward transition scheme):

  * states     — ``build_state_batch`` tiles the static per-unit
                 features and reads the decided-latency share from one
                 vectorized oracle call (``policy_latency_batch``,
                 numpy array ops over a (K, L) policy stack) instead of
                 K per-layer Python sweeps;
  * actions    — ``DDPGAgent.act_batch``: one actor forward over the
                 stacked states, row-wise truncated-normal exploration;
  * validation — one ``jit(vmap(accuracy))`` call over K stacked
                 cspecs and one batched oracle call, instead of K
                 sequential jit dispatches;
  * replay     — ``ReplayBuffer.push_batch`` bulk-inserts the K*T
                 transitions in one ring write.

Semantic differences vs the scalar loop, both at batch granularity:
critic/actor updates for the K episodes of a batch run after the whole
batch (same total update count) rather than interleaved between
episodes, and the state normalizer's running stats likewise advance
once per batch, so episodes within a batch act on the stats from the
previous batch boundary.
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import numpy as np

from repro.core.ddpg import DDPGAgent, DDPGConfig
from repro.core.latency import (V5E, HardwareTarget, LatencyContext,
                                policy_latency, policy_latency_batch)
from repro.core.policy import Policy, map_actions, stack_policies
from repro.core.replay import ReplayBuffer
from repro.core.reward import RewardConfig, compute_reward
from repro.core.sensitivity import SensitivityResult, run_sensitivity
from repro.core.spec import effective_bits
from repro.core.state import build_state, build_state_batch, state_dim


@dataclass(frozen=True)
class SearchConfig:
    methods: str = "pq"                # p | q | pq
    episodes: int = 120
    reward: RewardConfig = RewardConfig()
    ddpg: DDPGConfig = None            # filled in __post_init__ of the search
    seed: int = 0
    window: int = 0                    # attention window for the oracle
    track_bops: bool = True


@dataclass
class EpisodeRecord:
    episode: int
    reward: float
    accuracy: float
    latency_s: float
    latency_ratio: float
    macs_frac: float
    bops: float
    sigma: float
    policy: Policy = field(repr=False, default=None)


@dataclass
class SearchResult:
    history: List[EpisodeRecord]
    best: EpisodeRecord
    ref_latency_s: float
    ref_accuracy: float

    def best_under_budget(self, tol: float = 0.05) -> Optional[EpisodeRecord]:
        c = None
        for r in self.history:
            if r.latency_ratio <= (1.0 + tol):
                if c is None or r.accuracy > c.accuracy:
                    c = r
        return c


def _actionable(spec, methods: str) -> bool:
    if methods == "p":
        return spec.prunable and spec.prune_dim > 0
    if methods == "q":
        return spec.quantizable
    return spec.quantizable or (spec.prunable and spec.prune_dim > 0)


class CompressionSearch:
    """Owns: the compressible model, the sensitivity table, the latency
    oracle context, the agent, and the episode loop."""

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None):
        self.cmodel = cmodel
        self.specs = cmodel.specs
        self.cfg = search_cfg
        self.hw = hw
        self.ctx = ctx
        self.val_batch = val_batch
        a_dim = Policy([]).n_actions(search_cfg.methods)
        ddpg_cfg = search_cfg.ddpg or DDPGConfig(
            state_dim=state_dim(a_dim), action_dim=a_dim)
        if ddpg_cfg.state_dim != state_dim(a_dim):
            ddpg_cfg = DDPGConfig(**{**ddpg_cfg.__dict__,
                                     "state_dim": state_dim(a_dim),
                                     "action_dim": a_dim})
        self.agent = DDPGAgent(ddpg_cfg, seed=search_cfg.seed)
        self.replay = ReplayBuffer(ddpg_cfg.buffer_size, ddpg_cfg.state_dim,
                                   a_dim, seed=search_cfg.seed)
        self.sens = sens if sens is not None else run_sensitivity(
            cmodel, calib_batch if calib_batch is not None else val_batch)
        self._jit_acc = jax.jit(lambda cs: cmodel.accuracy(val_batch, cs))
        self.ref_policy = Policy.reference(self.specs)
        self.ref_lat = policy_latency(self.specs, self.ref_policy, hw, ctx,
                                      search_cfg.window)
        self.ref_acc = float(self._jit_acc(
            cmodel.build_cspec(self.ref_policy)))
        self.steps = [i for i, s in enumerate(self.specs)
                      if _actionable(s, search_cfg.methods)]

    # ------------------------------------------------------------------
    def run_episode(self, episode: int) -> EpisodeRecord:
        cfg = self.cfg
        warmup = episode < self.agent.cfg.warmup_episodes
        sigma = self.agent.sigma_at(episode)
        partial = copy.deepcopy(self.ref_policy)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros(a_dim, np.float32)
        states, actions = [], []
        for t in self.steps:
            s_vec = build_state(self.specs, t, partial, self.sens, prev_a,
                                self.hw, self.ctx, self.ref_lat, cfg.window)
            a = self.agent.act(s_vec, sigma, random=warmup)
            cmp = map_actions(self.specs[t], a, cfg.methods)
            # single-method agents preserve the other method's parameters
            # from the reference policy (supports the sequential scheme:
            # a frozen stage-1 policy as the starting point, paper App. A)
            prev = partial.cmps[t]
            if cfg.methods == "q":
                cmp.keep = prev.keep
            elif cfg.methods == "p":
                cmp.mode, cmp.w_bits, cmp.a_bits = (prev.mode, prev.w_bits,
                                                    prev.a_bits)
            partial.cmps[t] = cmp
            states.append(s_vec)
            actions.append(a)
            prev_a = a
        policy = partial

        cspec = self.cmodel.build_cspec(policy)
        acc = float(self._jit_acc(cspec))
        lat = policy_latency(self.specs, policy, self.hw, self.ctx,
                             cfg.window)
        reward = compute_reward(cfg.reward, acc, lat.total_s,
                                self.ref_lat.total_s)
        # push transitions — one shared episode reward (paper §Schema)
        self.agent.observe_states(np.stack(states))
        for i in range(len(states)):
            s_next = states[i + 1] if i + 1 < len(states) else states[i]
            done = i + 1 == len(states)
            self.replay.push(states[i], actions[i], reward, s_next, done)
        if not warmup:
            for _ in range(self.agent.cfg.updates_per_episode):
                self.agent.update(self.replay)

        ratio = lat.total_s / (cfg.reward.target_ratio *
                               self.ref_lat.total_s)
        return EpisodeRecord(
            episode=episode, reward=reward, accuracy=acc,
            latency_s=lat.total_s, latency_ratio=ratio,
            macs_frac=policy.macs_fraction(self.specs),
            bops=policy.bops(self.specs) if cfg.track_bops else 0.0,
            sigma=sigma, policy=policy)

    # chunking hooks: the scalar engine advances one episode at a time;
    # BatchedCompressionSearch overrides these to roll K per call
    def _chunk_size(self) -> int:
        return 1

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return [self.run_episode(first_episode)]

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> SearchResult:
        n = episodes or self.cfg.episodes
        history: List[EpisodeRecord] = []
        best = None
        e = 0
        while e < n:
            k = min(self._chunk_size(), n - e)
            for rec in self._run_chunk(e, k):
                history.append(rec)
                if best is None or rec.reward > best.reward:
                    best = rec
                if verbose and (rec.episode % 10 == 0
                                or rec.episode == n - 1):
                    print(f"  ep {rec.episode:4d} reward={rec.reward:+.4f} "
                          f"acc={rec.accuracy:.3f} "
                          f"lat_ratio={rec.latency_ratio:.3f} "
                          f"sigma={rec.sigma:.3f}")
            e += k
        return SearchResult(history=history, best=best,
                            ref_latency_s=self.ref_lat.total_s,
                            ref_accuracy=self.ref_acc)


class BatchedCompressionSearch(CompressionSearch):
    """K episodes per rollout; see the module docstring for the engine.

    Per-episode semantics (sigma schedule, warmup, shared episode
    reward, legality constraints) match ``CompressionSearch``; only the
    dispatch is amortized, so episode throughput scales with K.
    """

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, batch_size: int = 8):
        super().__init__(cmodel, val_batch, search_cfg, ctx, hw=hw,
                         sens=sens, calib_batch=calib_batch)
        self.batch_size = max(1, batch_size)

    # ------------------------------------------------------------------
    def run_episode_batch(self, first_episode: int,
                          k: int) -> List[EpisodeRecord]:
        cfg = self.cfg
        eps = list(range(first_episode, first_episode + k))
        warmup = np.asarray(
            [e < self.agent.cfg.warmup_episodes for e in eps])
        sigmas = np.asarray([self.agent.sigma_at(e) for e in eps],
                            np.float32)
        partials = [copy.deepcopy(self.ref_policy) for _ in eps]
        # (K, L) policy arrays, updated in place as units are decided
        pb = stack_policies(self.specs, partials)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros((k, a_dim), np.float32)
        step_states, step_actions = [], []
        for t in self.steps:
            cur = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                       cfg.window)
            S = build_state_batch(self.specs, t, cur, self.sens, prev_a,
                                  self.ref_lat)
            A = self.agent.act_batch(S, sigmas, warmup)
            for j in range(k):
                cmp = map_actions(self.specs[t], A[j], cfg.methods)
                prev = partials[j].cmps[t]
                if cfg.methods == "q":
                    cmp.keep = prev.keep
                elif cfg.methods == "p":
                    cmp.mode, cmp.w_bits, cmp.a_bits = (
                        prev.mode, prev.w_bits, prev.a_bits)
                partials[j].cmps[t] = cmp
                pb.keep[j, t] = cmp.keep
                pb.w_bits[j, t], pb.a_bits[j, t] = effective_bits(cmp)
            step_states.append(S)
            step_actions.append(A)
            prev_a = A

        # --- batched validation: one fused cspec+accuracy jit call and
        # one vectorized oracle call for the whole batch
        accs = np.asarray(
            self.cmodel.accuracy_policy_batch(self.val_batch, pb))
        lats = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                    cfg.window).total_s
        rewards = np.asarray([
            compute_reward(cfg.reward, float(accs[j]), float(lats[j]),
                           self.ref_lat.total_s) for j in range(k)])

        # --- transitions: (T, K, ·) -> per-episode chains, one bulk push
        T = len(self.steps)
        states = np.stack(step_states)            # (T, K, state_dim)
        actions = np.stack(step_actions)          # (T, K, a_dim)
        self.agent.observe_states(states.reshape(T * k, -1))
        nxt = np.concatenate([states[1:], states[-1:]])
        done = np.zeros((T, k), np.float32)
        done[-1] = 1.0
        order = lambda x: x.swapaxes(0, 1).reshape(T * k, *x.shape[2:])
        self.replay.push_batch(
            order(states), order(actions),
            np.repeat(rewards, T).astype(np.float32),
            order(nxt), order(done))
        n_live = int((~warmup).sum())
        for _ in range(self.agent.cfg.updates_per_episode * n_live):
            self.agent.update(self.replay)

        records = []
        for j, e in enumerate(eps):
            pol = partials[j]
            ratio = float(lats[j]) / (cfg.reward.target_ratio *
                                      self.ref_lat.total_s)
            records.append(EpisodeRecord(
                episode=e, reward=float(rewards[j]),
                accuracy=float(accs[j]), latency_s=float(lats[j]),
                latency_ratio=ratio,
                macs_frac=pol.macs_fraction(self.specs),
                bops=pol.bops(self.specs) if cfg.track_bops else 0.0,
                sigma=float(sigmas[j]), policy=pol))
        return records

    def _chunk_size(self) -> int:
        return self.batch_size

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return self.run_episode_batch(first_episode, k)
