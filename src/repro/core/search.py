"""The Galen search loop (paper Fig. 1/2): episodes of layer-wise policy
prediction, hardware-oracle validation, and DDPG optimization.

Three agents (paper §Proposed Agents) share this loop and differ only in
``methods``:  "p" (pruning), "q" (quantization), "pq" (joint).

How the episode engines work
----------------------------
Three engines share the per-episode semantics (sigma decay schedule,
warmup flags, shared-episode-reward transition scheme, hardware
legality) and differ only in how much of an episode batch runs per
host dispatch:

* ``CompressionSearch.run_episode`` — the scalar reference path: walk
  the actionable units in order, build the agent state (which probes
  the analytic latency oracle under the partial policy), act, map the
  continuous action to a legal CMP, then validate the finished policy
  (one jitted accuracy eval + one oracle call) and push the transitions
  with the shared episode reward.

* ``BatchedCompressionSearch`` — K episodes per rollout, still L host
  steps: ``build_state_batch`` + one vectorized numpy oracle call per
  layer step, ``DDPGAgent.act_batch`` (host numpy actor), a Python
  ``map_actions`` loop over the K episodes, then one fused
  cspec+accuracy jit call and a single bulk ring write.

* ``FusedCompressionSearch`` — the whole K-episode rollout is ONE
  ``jit(lax.scan)`` over the layer steps: a traceable ``JaxBatchOracle``
  builds the latency features, ``agent_act_batch`` runs the actor (with
  in-scan PRNG for warmup/sigma exploration), ``map_actions_batch``
  projects actions to legal CMPs as array ops, and the (K, L) policy
  arrays live in the scan carry. Validation and learning then reuse the
  fused paths (``accuracy_policy_batch`` + ``update_chunk``).

* epoch mode (``FusedCompressionSearch(..., epoch_batches=E)`` /
  ``run_epoch``) — E whole episode batches as ONE ``jit(lax.scan)``
  over batches: the scan body chains the fused rollout, the traced-
  cspec validation, the reward, the ``DeviceReplay`` ring write, and
  the update chunk as pure carry transitions over ``(AgentState, ring,
  rollout PRNG, best-policy argmax)``. Metrics come back as (E, K)
  device arrays with exactly one host readback per epoch; agent/ring
  buffers are donated to the epoch executable so they update in place.

Cost per episode batch (K episodes over L actionable units,
post-compile; u = fused update-chunk dispatches):

  ========  ====================  ===========================
  engine    host environment      jit dispatches
            steps per batch       per batch
  ========  ====================  ===========================
  scalar    K * L                 2K + u   (accuracy + ring
                                  write per episode)
  batched   L                     2 + u    (fused validation
                                  + one bulk ring write)
  fused     0                     3 + u    (<= 4 total)
  epoch     0                     1 / E    (one dispatch and
                                  one readback per E batches)
  ========  ====================  ===========================

A "host environment step" is one oracle probe + state build + actor
forward + action->CMP mapping round-trip on the host; the fused
engine's three dispatches are rollout, validation, and the replay ring
write (its ``dispatch_log`` records them so benchmarks can assert the
count never regresses; epoch mode logs one ``"epoch"`` entry per E
batches). The numpy engines stay as the parity references —
``tests/test_fused.py`` property-tests the fused rollout against
``BatchedCompressionSearch`` step for step, and ``tests/test_epoch.py``
property-tests epoch mode against the per-batch fused engine (records,
final ``AgentState``, ring contents).

Where the learning happens (PR 2: the functional agent core)
-----------------------------------------------------------
Both engines store transitions in a device-resident ``DeviceReplay``
(``core/replay.py``) and dispatch *all* of an episode batch's critic/
actor/target updates as ONE jitted ``lax.scan`` —
``DDPGAgent.update_chunk`` over the ``AgentState`` pytree
(``core/ddpg.py``). Replay sampling, reward moving-average centering,
state standardization, and the Adam/soft-target math all run inside the
scan; the only host sync per episode batch is the loss array. The
scalar engine fuses its ``updates_per_episode`` steps the same way, so
the two paths differ only in rollout batching.

``PopulationSearch`` stacks P member searches (p/q/pq agents, multiple
seeds, or one member per hardware target) and replaces their P separate
update dispatches with one ``jit(vmap(update_chunk))`` over the stacked
``AgentState``/replay pytrees. Members with different native action
dimensionalities share one population by padding ``action_dim`` to the
maximum (``map_actions`` consumes a prefix of the action vector, so
trailing entries are inert for single-method agents). With
``fuse_rollouts=True`` and ``FusedCompressionSearch`` members that
share a step list (same methods — e.g. one member per hardware target,
whose rate parameters enter the traced oracle as a vmappable
``HwParams`` pytree), the P rollout dispatches also collapse into one
``jit(vmap(rollout))``.

Semantic notes, both at batch granularity: critic/actor updates for the
K episodes of a batch run after the whole batch (same total update
count) rather than interleaved between episodes, and the state
normalizer's running stats advance once per batch, so episodes within a
batch act on the stats from the previous batch boundary. Within an
update chunk the normalizer snapshot is frozen and the reward moving
average advances per step — exactly the scalar ``DDPGAgent.update``
semantics, property-tested in ``tests/test_agent_core.py``.
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointing import (AsyncCheckpointer, restore_latest,
                                            save_async)
from repro.distributed.fault_tolerance import (FaultToleranceConfig,
                                               StepMonitor)
from repro.distributed.sharding import pad_members, population_shardings

from repro.core.constraints import legal_tables
from repro.core.ddpg import (_SCAN_UNROLL as _UPDATE_SCAN_UNROLL,
                             DDPGAgent, DDPGConfig, agent_act_batch,
                             chunk_sample_keys, observe_states_pure,
                             population_update_chunk, tree_index,
                             tree_stack, update_step)
from repro.core.latency import (V5E, HardwareTarget, LatencyContext,
                                fifo_cached, get_jax_oracle, policy_latency,
                                policy_latency_batch)
from repro.core.policy import (Policy, PolicyBatch, action_columns,
                               map_actions, map_actions_batch, n_actions,
                               policies_from_batch, stack_policies)
from repro.core.replay import (DeviceReplay, device_replay_push,
                               device_replay_sample)
from repro.core.reward import RewardConfig, compute_reward, \
    compute_reward_batch
from repro.core.sensitivity import SensitivityResult, run_sensitivity
from repro.core.spec import effective_bits
from repro.core.state import (StateTables, build_state, build_state_batch,
                              fused_state_block, state_dim)


@dataclass(frozen=True)
class SearchConfig:
    methods: str = "pq"                # p | q | pq
    episodes: int = 120
    reward: RewardConfig = field(default_factory=RewardConfig)
    ddpg: Optional[DDPGConfig] = None  # None -> sized to the method set
    seed: int = 0
    window: int = 0                    # attention window for the oracle
    track_bops: bool = True
    # latency oracle flavor (core/measure.py):
    #   analytic   — pure roofline (the default, zero measurement deps)
    #   calibrated — roofline terms rescaled by the fitted per-(kind,
    #                container) factors; stays fully traced/batched
    #   measured   — calibrated search + wall-clock re-timing of the
    #                top-K final candidates (SearchResult.measured)
    oracle_mode: str = "analytic"
    calibration_path: str = ""         # "" -> artifacts/latency_calibration.json
    measure_top_k: int = 3             # distinct candidates re-timed


@dataclass
class EpisodeRecord:
    episode: int
    reward: float
    accuracy: float
    latency_s: float
    latency_ratio: float
    macs_frac: float
    bops: float
    sigma: float
    policy: Policy = field(repr=False, default=None)


@dataclass
class SearchResult:
    history: List[EpisodeRecord]
    best: EpisodeRecord
    ref_latency_s: float
    ref_accuracy: float
    # oracle_mode="measured": wall-clock rows for the top-K candidates
    # (predicted vs measured seconds and ratios vs the reference model)
    measured: Optional[List[dict]] = None

    def best_under_budget(self, tol: float = 0.05) -> Optional[EpisodeRecord]:
        c = None
        for r in self.history:
            if r.latency_ratio <= (1.0 + tol):
                if c is None or r.accuracy > c.accuracy:
                    c = r
        return c


def _actionable(spec, methods: str) -> bool:
    if methods == "p":
        return spec.prunable and spec.prune_dim > 0
    if methods == "q":
        return spec.quantizable
    return spec.quantizable or (spec.prunable and spec.prune_dim > 0)


class CompressionSearch:
    """Owns: the compressible model, the sensitivity table, the latency
    oracle context, the agent, and the episode loop."""

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, calib=None):
        self.cmodel = cmodel
        self.specs = cmodel.specs
        self.cfg = search_cfg
        self.hw = hw
        self.ctx = ctx
        self.val_batch = val_batch
        # latency-oracle flavor: a CalibrationTable rescales every oracle
        # form's terms in calibrated/measured mode; analytic ignores it
        mode = search_cfg.oracle_mode
        if mode not in ("analytic", "calibrated", "measured"):
            raise ValueError(
                f"SearchConfig.oracle_mode must be analytic|calibrated|"
                f"measured, got {mode!r}")
        if mode != "analytic" and calib is None:
            from repro.core.measure import load_calibration
            calib = load_calibration(search_cfg.calibration_path or None)
        self.calib = calib if mode != "analytic" else None
        native = n_actions(search_cfg.methods)
        ddpg_cfg = search_cfg.ddpg or DDPGConfig(
            state_dim=state_dim(native), action_dim=native)
        # a provided action_dim larger than the method's native one pads
        # the action space (population members must share shapes); a
        # smaller one is corrected up to native
        a_dim = max(native, ddpg_cfg.action_dim)
        if (ddpg_cfg.state_dim, ddpg_cfg.action_dim) != (state_dim(a_dim),
                                                         a_dim):
            ddpg_cfg = DDPGConfig(**{**ddpg_cfg.__dict__,
                                     "state_dim": state_dim(a_dim),
                                     "action_dim": a_dim})
        self.agent = DDPGAgent(ddpg_cfg, seed=search_cfg.seed)
        self.replay = DeviceReplay(ddpg_cfg.buffer_size, ddpg_cfg.state_dim,
                                   a_dim, seed=search_cfg.seed)
        # fused + memoized (ONE jit execution for the whole layer×probe
        # grid, shared across every engine built on the same model and
        # calibration batch — population members included)
        self.sens = sens if sens is not None else run_sensitivity(
            cmodel, calib_batch if calib_batch is not None else val_batch)
        self._jit_acc = jax.jit(lambda cs: cmodel.accuracy(val_batch, cs))
        self.ref_policy = Policy.reference(self.specs)
        self.ref_lat = policy_latency(self.specs, self.ref_policy, hw, ctx,
                                      search_cfg.window, calib=self.calib)
        self.ref_acc = float(self._jit_acc(
            cmodel.build_cspec(self.ref_policy)))
        self.steps = [i for i, s in enumerate(self.specs)
                      if _actionable(s, search_cfg.methods)]
        self._pending_updates = 0
        self._defer_updates = False     # PopulationSearch batches flushes

    # ------------------------------------------------------------------
    def _flush_updates(self):
        """Dispatch the accumulated update budget as one fused chunk."""
        n = self._pending_updates
        self._pending_updates = 0
        if n > 0 and len(self.replay) >= self.agent.cfg.batch_size:
            self.agent.update_chunk(self.replay, n)

    def _queue_updates(self, n: int):
        self._pending_updates += n
        if not self._defer_updates:
            self._flush_updates()

    # ------------------------------------------------------------------
    def run_episode(self, episode: int) -> EpisodeRecord:
        cfg = self.cfg
        warmup = episode < self.agent.cfg.warmup_episodes
        sigma = self.agent.sigma_at(episode)
        partial = copy.deepcopy(self.ref_policy)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros(a_dim, np.float32)
        states, actions = [], []
        for t in self.steps:
            s_vec = build_state(self.specs, t, partial, self.sens, prev_a,
                                self.hw, self.ctx, self.ref_lat, cfg.window)
            a = self.agent.act(s_vec, sigma, random=warmup)
            cmp = map_actions(self.specs[t], a, cfg.methods)
            # single-method agents preserve the other method's parameters
            # from the reference policy (supports the sequential scheme:
            # a frozen stage-1 policy as the starting point, paper App. A)
            prev = partial.cmps[t]
            if cfg.methods == "q":
                cmp.keep = prev.keep
            elif cfg.methods == "p":
                cmp.mode, cmp.w_bits, cmp.a_bits = (prev.mode, prev.w_bits,
                                                    prev.a_bits)
            partial.cmps[t] = cmp
            states.append(s_vec)
            actions.append(a)
            prev_a = a
        policy = partial

        cspec = self.cmodel.build_cspec(policy)
        acc = float(self._jit_acc(cspec))
        lat = policy_latency(self.specs, policy, self.hw, self.ctx,
                             cfg.window, calib=self.calib)
        reward = compute_reward(cfg.reward, acc, lat.total_s,
                                self.ref_lat.total_s)
        # push transitions — one shared episode reward (paper §Schema),
        # one bulk ring write for the whole chain
        T = len(states)
        st_arr = np.stack(states)
        self.agent.observe_states(st_arr)
        nxt = np.concatenate([st_arr[1:], st_arr[-1:]])
        done = np.zeros(T, np.float32)
        done[-1] = 1.0
        self.replay.push_batch(st_arr, np.stack(actions),
                               np.full(T, reward, np.float32), nxt, done)
        if not warmup:
            self._queue_updates(self.agent.cfg.updates_per_episode)

        ratio = lat.total_s / (cfg.reward.target_ratio *
                               self.ref_lat.total_s)
        return EpisodeRecord(
            episode=episode, reward=reward, accuracy=acc,
            latency_s=lat.total_s, latency_ratio=ratio,
            macs_frac=policy.macs_fraction(self.specs),
            bops=policy.bops(self.specs) if cfg.track_bops else 0.0,
            sigma=sigma, policy=policy)

    # chunking hooks: the scalar engine advances one episode at a time;
    # BatchedCompressionSearch overrides these to roll K per call
    def _chunk_size(self) -> int:
        return 1

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return [self.run_episode(first_episode)]

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> SearchResult:
        n = episodes or self.cfg.episodes
        history: List[EpisodeRecord] = []
        best = None
        e = 0
        while e < n:
            k = min(self._chunk_size(), n - e)
            for rec in self._run_chunk(e, k):
                history.append(rec)
                if best is None or rec.reward > best.reward:
                    best = rec
                if verbose and (rec.episode % 10 == 0
                                or rec.episode == n - 1):
                    print(f"  ep {rec.episode:4d} reward={rec.reward:+.4f} "
                          f"acc={rec.accuracy:.3f} "
                          f"lat_ratio={rec.latency_ratio:.3f} "
                          f"sigma={rec.sigma:.3f}")
            e += k
        result = SearchResult(history=history, best=best,
                              ref_latency_s=self.ref_lat.total_s,
                              ref_accuracy=self.ref_acc)
        if self.cfg.oracle_mode == "measured":
            result.measured = self._measure_top_k(history)
        return result

    def _measure_top_k(self, history: List[EpisodeRecord]) -> List[dict]:
        """Wall-clock the deployed forward of the top-K candidates (the
        paper's measure-on-target step, applied only to finalists). The
        measurement memo is FIFO-cached by container signature, so
        candidates sharing a deployment are timed once."""
        from repro.core import measure
        k = max(1, self.cfg.measure_top_k)
        top = sorted(history, key=lambda r: r.reward, reverse=True)[:k]
        ref_s = measure.measure_policy(self.cmodel, self.ref_policy,
                                       self.val_batch)
        rows = []
        for r in top:
            t = measure.measure_policy(self.cmodel, r.policy,
                                       self.val_batch)
            rows.append({
                "episode": r.episode, "reward": r.reward,
                "predicted_s": r.latency_s,
                "predicted_ratio": r.latency_s / self.ref_lat.total_s,
                "measured_s": t, "measured_ref_s": ref_s,
                "measured_ratio": t / ref_s if ref_s > 0 else float("inf"),
            })
        return rows


class BatchedCompressionSearch(CompressionSearch):
    """K episodes per rollout; see the module docstring for the engine.

    Per-episode semantics (sigma schedule, warmup, shared episode
    reward, legality constraints) match ``CompressionSearch``; only the
    dispatch is amortized, so episode throughput scales with K.
    """

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, calib=None, batch_size: int = 8):
        super().__init__(cmodel, val_batch, search_cfg, ctx, hw=hw,
                         sens=sens, calib_batch=calib_batch, calib=calib)
        self.batch_size = max(1, batch_size)

    # ------------------------------------------------------------------
    def _batch_schedule(self, first_episode: int, k: int):
        """(warmup mask, sigma) per episode row — THE one place the
        batch's exploration schedule is derived (rollout and
        finish/record paths must agree on it)."""
        eps = range(first_episode, first_episode + k)
        warmup = np.asarray(
            [e < self.agent.cfg.warmup_episodes for e in eps])
        sigmas = np.asarray([self.agent.sigma_at(e) for e in eps],
                            np.float32)
        return warmup, sigmas

    def run_episode_batch(self, first_episode: int,
                          k: int) -> List[EpisodeRecord]:
        cfg = self.cfg
        eps = list(range(first_episode, first_episode + k))
        warmup, sigmas = self._batch_schedule(first_episode, k)
        partials = [copy.deepcopy(self.ref_policy) for _ in eps]
        # (K, L) policy arrays, updated in place as units are decided
        pb = stack_policies(self.specs, partials)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros((k, a_dim), np.float32)
        step_states, step_actions = [], []
        for t in self.steps:
            cur = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                       cfg.window, calib=self.calib)
            S = build_state_batch(self.specs, t, cur, self.sens, prev_a,
                                  self.ref_lat)
            A = self.agent.act_batch(S, sigmas, warmup)
            for j in range(k):
                cmp = map_actions(self.specs[t], A[j], cfg.methods)
                prev = partials[j].cmps[t]
                if cfg.methods == "q":
                    cmp.keep = prev.keep
                elif cfg.methods == "p":
                    cmp.mode, cmp.w_bits, cmp.a_bits = (
                        prev.mode, prev.w_bits, prev.a_bits)
                partials[j].cmps[t] = cmp
                pb.keep[j, t] = cmp.keep
                pb.w_bits[j, t], pb.a_bits[j, t] = effective_bits(cmp)
            step_states.append(S)
            step_actions.append(A)
            prev_a = A

        # --- batched validation: one fused cspec+accuracy jit call and
        # one vectorized oracle call for the whole batch
        accs = np.asarray(
            self.cmodel.accuracy_policy_batch(self.val_batch, pb))
        lats = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                    cfg.window, calib=self.calib).total_s
        rewards = compute_reward_batch(cfg.reward, accs, lats,
                                       self.ref_lat.total_s, xp=np)
        return self._push_and_record(
            eps, warmup, sigmas, partials, np.stack(step_states),
            np.stack(step_actions), accs, lats, rewards)

    def _log_dispatch(self, label: str):
        """Hook for engines that account their jit dispatches (the
        fused engine's ``dispatch_log``); no-op here."""

    def _push_and_record(self, eps, warmup, sigmas, pols, states,
                         actions, accs, lats,
                         rewards) -> List[EpisodeRecord]:
        """The engines' shared batch tail — THE definition of the
        shared-episode-reward transition scheme: observe the (T, K, ·)
        states, push per-episode chains as one bulk ring write
        (reward repeated along each chain, done on the last step),
        queue the live episodes' update budget, and build the records.
        """
        cfg = self.cfg
        T, k = len(self.steps), len(eps)
        self.agent.observe_states(states.reshape(T * k, -1))
        nxt = np.concatenate([states[1:], states[-1:]])
        done = np.zeros((T, k), np.float32)
        done[-1] = 1.0
        order = lambda x: x.swapaxes(0, 1).reshape(T * k, *x.shape[2:])
        self.replay.push_batch(
            order(states), order(actions),
            np.repeat(rewards, T).astype(np.float32),
            order(nxt), order(done))
        self._log_dispatch("push")
        n_live = int((~warmup).sum())
        self._queue_updates(self.agent.cfg.updates_per_episode * n_live)

        # record tail: ONE bulk conversion per batch (a single
        # np.asarray readback each), not per-episode scalar float()s
        acc_l, lat_l, rew_l, sig_l = (
            np.asarray(x, np.float64).tolist()
            for x in (accs, lats, rewards, sigmas))
        denom = cfg.reward.target_ratio * self.ref_lat.total_s
        records = []
        for j, e in enumerate(eps):
            records.append(EpisodeRecord(
                episode=e, reward=rew_l[j],
                accuracy=acc_l[j], latency_s=lat_l[j],
                latency_ratio=lat_l[j] / denom,
                macs_frac=pols[j].macs_fraction(self.specs),
                bops=pols[j].bops(self.specs) if cfg.track_bops else 0.0,
                sigma=sig_l[j], policy=pols[j]))
        return records

    def _chunk_size(self) -> int:
        return self.batch_size

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return self.run_episode_batch(first_episode, k)


# ===========================================================================
# Fused engine: the rollout environment as one jit(lax.scan)
# ===========================================================================

class MethodCols(NamedTuple):
    """Which action columns feed pruning/quantization, and whether each
    method is live — as traced values, so the rollout step function is
    method-agnostic (one compiled form serves p/q/pq and the columns
    vmap across a population)."""
    ip: jnp.ndarray            # () i32  prune-ratio action column
    iw: jnp.ndarray            # () i32  weight-bits action column
    ia: jnp.ndarray            # () i32  act-bits action column
    do_p: jnp.ndarray          # () bool method prunes
    do_q: jnp.ndarray          # () bool method quantizes


def method_cols(methods: str) -> MethodCols:
    ip, iw, ia = action_columns(methods)
    return MethodCols(
        ip=jnp.asarray(ip, jnp.int32), iw=jnp.asarray(iw, jnp.int32),
        ia=jnp.asarray(ia, jnp.int32),
        do_p=jnp.asarray("p" in methods), do_q=jnp.asarray("q" in methods))


def make_rollout_fn(cfg: DDPGConfig, oracle, legal, static_tab, spec_steps):
    """Build the pure rollout function the fused engine jits (and the
    population engine ``jit(vmap)``s).

    Closure constants: the agent config, the traceable oracle (specs/
    context tables; hardware rates stay in the ``hwp`` argument), the
    legality tables, the (T, S) static feature rows, and the (T,) spec
    index per step. Everything hardware- or member-specific is an
    argument so one traced function serves a vmapped stack of members.

    Returns ``rollout(st, keep0, wb0, ab0, sigmas, warmup, hwp, shares,
    ref_total, cols, keys) -> (keep, wb, ab, states, actions, lats)``
    with ``states``/``actions`` stacked (T, K, ·) in step order and
    ``lats`` the final policies' oracle latency — the whole episode
    environment in one dispatch.
    """
    pd = jnp.asarray(legal.prune_dim)
    gran = jnp.asarray(legal.granularity)
    prunable = jnp.asarray(legal.prunable)
    quantizable = jnp.asarray(legal.quantizable)
    mix_ok = jnp.asarray(legal.mix_ok)
    static_tab = jnp.asarray(static_tab)
    spec_steps = jnp.asarray(spec_steps)

    def rollout(st, keep0, wb0, ab0, sigmas, warmup, hwp, shares,
                ref_total, cols, keys):
        K = sigmas.shape[0]
        L = keep0.shape[-1]
        init = (jnp.broadcast_to(keep0, (K, L)),
                jnp.broadcast_to(wb0, (K, L)),
                jnp.broadcast_to(ab0, (K, L)),
                jnp.zeros((K, cfg.action_dim), jnp.float32))

        def step(carry, x):
            keep, wb, ab, prev_a = carry
            t, static_row, share_row, k = x
            unit_t, extra_t = oracle.unit_times(keep, wb, ab, hwp)
            decided = oracle.decided_before(unit_t, extra_t, t) / ref_total
            S = fused_state_block(static_row, share_row, decided, prev_a)
            A = agent_act_batch(cfg, st, S, k, sigmas, warmup)
            new_keep, new_wb, new_ab = map_actions_batch(
                A, prune_dim=pd[t], granularity=gran[t],
                prunable=prunable[t], quantizable=quantizable[t],
                mix_ok=mix_ok[t], ip=cols.ip, iw=cols.iw, ia=cols.ia)
            # single-method agents preserve the other method's reference
            # parameters (same rule as the host engines)
            keep = keep.at[:, t].set(
                jnp.where(cols.do_p, new_keep, keep[:, t]))
            wb = wb.at[:, t].set(jnp.where(cols.do_q, new_wb, wb[:, t]))
            ab = ab.at[:, t].set(jnp.where(cols.do_q, new_ab, ab[:, t]))
            return (keep, wb, ab, A), (S, A)

        xs = (spec_steps, static_tab, shares, keys)
        (keep, wb, ab, _), (states, actions) = jax.lax.scan(step, init, xs)
        unit_t, extra_t = oracle.unit_times(keep, wb, ab, hwp)
        lats = oracle.totals(unit_t, extra_t, hwp)
        return keep, wb, ab, states, actions, lats

    return rollout


# ===========================================================================
# Epoch-fused engine: E episode batches as one jit(lax.scan)
# ===========================================================================

def _schedule_segments(schedule: tuple) -> List[tuple]:
    """Group a static update schedule into (n_updates, batch count)
    runs of consecutive equal entries: (32, 64, 64, 64) -> [(32, 1),
    (64, 3)]. Each run becomes its own scan with an UNMASKED inner
    update scan of exactly n steps — no wasted masked GEMMs, no
    per-step tree selects, and the same op sequence as the per-batch
    ``update_chunk``. Steady-state epochs are one segment."""
    segs: List[tuple] = []
    for n in schedule:
        if segs and segs[-1][0] == n:
            segs[-1] = (n, segs[-1][1] + 1)
        else:
            segs.append((n, 1))
    return segs


def make_epoch_fn(cfg: DDPGConfig, reward_cfg: RewardConfig, rollout_fn,
                  acc_fn, T: int, K: int, schedule: tuple):
    """Build the pure epoch function: E = len(schedule) episode batches
    as one traced program — a ``lax.scan`` per schedule segment whose
    body chains the fused rollout, the traced-cspec validation
    (``acc_fn``), the reward, the replay ring write, and the update
    scan as carry transitions over ``(AgentState, DeviceReplayData,
    rollout PRNG key, best)``.

    ``schedule`` is the STATIC per-batch fused-update step count (see
    ``FusedCompressionSearch._update_schedule``): the update-sampling
    keys every batch will consume are derived at trace time with the
    exact ``chunk_sample_keys`` splits the per-batch path performs —
    ``jax.random.split`` is not prefix-stable across lengths, so a
    traced count could not reproduce them — and consecutive equal
    counts share one scan (``_schedule_segments``), so every batch runs
    exactly its budget. Steady-state epochs all share one schedule,
    hence one compiled executable (FIFO-cached by the engine).
    Everything member-specific is an argument, so a population can
    ``jit(vmap)`` one epoch function across stacked members.

    Returns ``epoch(st, ring, rkey, keep0, wb0, ab0, sigmas, warmup,
    hwp, shares, ref_total, cols, ref_total_s) -> (st, ring, rkey,
    best, ys)`` with ``ys = (accs, lats, rewards, keep, wb, ab)``
    stacked (E, ...) — the device-side metrics read back in one
    transfer — and ``best = (reward, episode offset, (keep, wb, ab))``
    the in-carry argmax over the epoch's E*K episodes.
    """
    segments = _schedule_segments(schedule)

    def epoch(st, ring, rkey, keep0, wb0, ab0, sigmas, warmup, hwp,
              shares, ref_total, cols, ref_total_s):
        # trace-time sample-key schedule (zero runtime dispatches):
        # consume st.key exactly as E per-batch update_chunk calls would
        key = st.key
        seg_keys = []
        for n, cnt in segments:
            if n > 0:
                ks = []
                for _ in range(cnt):
                    key, sk = chunk_sample_keys(key, n)
                    ks.append(sk)
                seg_keys.append(jnp.stack(ks))      # (cnt, n, key)
            else:
                seg_keys.append(None)
        final_key = key

        def make_body(n):
            def body(carry, x):
                st, ring, rk, best = carry
                (e, sig, warm), skeys = x[:3], (x[3] if n > 0 else None)
                rk, bk = jax.random.split(rk)
                keys = jax.random.split(bk, T)
                keep, wb, ab, states, actions, lats = rollout_fn(
                    st, keep0, wb0, ab0, sig, warm, hwp, shares,
                    ref_total, cols, keys)
                # the normalizer advances at the batch boundary, exactly
                # as the host engines' observe_states does
                st = observe_states_pure(st, states.reshape(T * K, -1))
                accs = acc_fn(keep.astype(jnp.int32),
                              wb.astype(jnp.int32), ab.astype(jnp.int32))
                rewards = compute_reward_batch(reward_cfg, accs, lats,
                                               ref_total_s)
                order = lambda z: jnp.swapaxes(z, 0, 1).reshape(
                    T * K, *z.shape[2:])
                nxt = jnp.concatenate([states[1:], states[-1:]])
                done = jnp.zeros((T, K), jnp.float32).at[-1].set(1.0)
                ring = device_replay_push(
                    ring, order(states), order(actions),
                    jnp.repeat(rewards, T).astype(jnp.float32),
                    order(nxt), order(done))
                if n > 0:     # this batch's update chunk, in-scan
                    def ustep(c, k2):
                        batch = device_replay_sample(ring, k2,
                                                     cfg.batch_size)
                        return update_step(cfg, c, batch)

                    st, _losses = jax.lax.scan(
                        ustep, st, skeys,
                        unroll=min(_UPDATE_SCAN_UNROLL, n))
                # in-carry best-policy tracking; strict > keeps the
                # earliest argmax, the rule run()'s host loop applies
                j = jnp.argmax(rewards)
                better = rewards[j] > best[0]
                pick = lambda a, b: jnp.where(better, a, b)
                best = (pick(rewards[j], best[0]),
                        pick(e * K + j, best[1]),
                        jax.tree.map(pick, (keep[j], wb[j], ab[j]),
                                     best[2]))
                return (st, ring, rk, best), (accs, lats, rewards, keep,
                                              wb, ab)

            return body

        L = keep0.shape[-1]
        best0 = (jnp.asarray(-jnp.inf, jnp.float32),
                 jnp.zeros((), jnp.int32),
                 tuple(jnp.zeros((L,), jnp.float32) for _ in range(3)))
        carry = (st, ring, rkey, best0)
        outs, base = [], 0
        for (n, cnt), sk in zip(segments, seg_keys):
            xs = (jnp.arange(base, base + cnt, dtype=jnp.int32),
                  sigmas[base:base + cnt], warmup[base:base + cnt])
            if n > 0:
                xs = xs + (sk,)
            carry, ys = jax.lax.scan(make_body(n), carry, xs)
            outs.append(ys)
            base += cnt
        st, ring, rk, best = carry
        ys = outs[0] if len(outs) == 1 else jax.tree.map(
            lambda *zs: jnp.concatenate(zs, axis=0), *outs)
        return st._replace(key=final_key), ring, rk, best, ys

    return epoch


_EPOCH_CACHE_MAX = 16


class FusedCompressionSearch(BatchedCompressionSearch):
    """K episodes per rollout, the rollout itself ONE jit dispatch.

    Same per-episode semantics as the numpy engines; the environment
    (oracle features, actor, action->CMP projection, policy carry) runs
    as a ``lax.scan`` over the layer steps, so an episode batch costs
    rollout + validation + ring write + update chunk — at most 4 jit
    executions — instead of ~2L host dispatches. ``dispatch_log``
    records each fused-path dispatch ("rollout"/"validate"/"push"/
    "update"); the weekly benchmark cross-checks it against measured
    invocations of the compiled entry points
    (``benchmarks.search_setup.fused_dispatch_probe``). In a fused
    population, dispatches shared across members (rollout, update)
    appear in every member's log.

    Exploration randomness comes from a dedicated jax PRNG stream
    (``seed``-derived, separate from the agent's update-sampling key);
    ``_last_batch_key`` exposes the per-batch key so parity tests can
    replay the exact draws through the numpy reference engine.

    With ``epoch_batches=E > 0`` the engine runs in epoch mode:
    ``run()`` dispatches E batches at a time through ``run_epoch`` —
    one jit execution (agent/ring buffers donated, so they update in
    place) and one host readback per epoch, instead of <= 4 dispatches
    and per-batch syncs. The epoch scan carries the same PRNG streams
    and consumes them with the same split pattern as the per-batch
    path, so a same-seed per-batch engine reproduces an epoch run
    draw for draw (``tests/test_epoch.py``).
    """

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, calib=None, batch_size: int = 8,
                 epoch_batches: int = 0):
        super().__init__(cmodel, val_batch, search_cfg, ctx, hw=hw,
                         sens=sens, calib_batch=calib_batch, calib=calib,
                         batch_size=batch_size)
        # calibration factors enter the traced oracle as constants —
        # calibrated mode keeps the rollout at its 1-dispatch bound
        self.oracle = get_jax_oracle(self.specs, hw, ctx, search_cfg.window,
                                     calib=self.calib)
        self.tables = StateTables(self.specs, self.steps, self.sens,
                                  self.ref_lat)
        ref_pb = stack_policies(self.specs, [self.ref_policy])
        self._ref_rows = tuple(
            jnp.asarray(x[0], jnp.float32)
            for x in (ref_pb.keep, ref_pb.w_bits, ref_pb.a_bits))
        self._cols = method_cols(search_cfg.methods)
        self._rollout_fn = make_rollout_fn(
            self.agent.cfg, self.oracle, legal_tables(self.specs),
            self.tables.static, self.tables.spec_idx)
        self._rollout = jax.jit(self._rollout_fn)
        self._rollout_key = jax.random.PRNGKey(search_cfg.seed + 0x5EED)
        self._last_batch_key = None
        self.dispatch_log: List[str] = []
        # epoch mode: run() rolls E batches per run_epoch dispatch
        self.epoch_batches = max(0, epoch_batches)
        self._epoch_cache: dict = {}
        self.last_epoch_best: Optional[tuple] = None

    # ------------------------------------------------------------------
    def _rollout_args(self, first_episode: int, k: int) -> tuple:
        """Per-batch argument tuple for ``_rollout_fn`` (every element
        stackable across population members); advances the rollout PRNG
        stream."""
        warmup, sigmas = self._batch_schedule(first_episode, k)
        self._rollout_key, bk = jax.random.split(self._rollout_key)
        self._last_batch_key = bk
        keys = jax.random.split(bk, len(self.steps))
        keep0, wb0, ab0 = self._ref_rows
        return (self.agent.state_for_dispatch(), keep0, wb0, ab0,
                jnp.asarray(sigmas), jnp.asarray(warmup), self.oracle.hwp,
                jnp.asarray(self.tables.shares),
                jnp.asarray(self.tables.ref_total, jnp.float32),
                self._cols, keys)

    def _finish_batch(self, first_episode: int, k: int,
                      out: tuple) -> List[EpisodeRecord]:
        """Validation, reward, replay write, records — everything after
        the rollout dispatch. ``out`` is a ``_rollout_fn`` result."""
        cfg = self.cfg
        keep, wb, ab, dev_states, dev_actions, lats = out
        eps = list(range(first_episode, first_episode + k))
        warmup, sigmas = self._batch_schedule(first_episode, k)
        pb = PolicyBatch(keep=np.asarray(keep, np.float64),
                         w_bits=np.asarray(wb, np.float64),
                         a_bits=np.asarray(ab, np.float64))
        accs = np.asarray(
            self.cmodel.accuracy_policy_batch(self.val_batch, pb))
        self.dispatch_log.append("validate")
        lats = np.asarray(lats, np.float64)
        rewards = np.asarray(compute_reward_batch(
            cfg.reward, accs.astype(np.float32),
            lats.astype(np.float32), self.ref_lat.total_s), np.float64)
        return self._push_and_record(
            eps, warmup, sigmas, policies_from_batch(self.specs, pb),
            np.asarray(dev_states), np.asarray(dev_actions), accs, lats,
            rewards)

    def _log_dispatch(self, label: str):
        self.dispatch_log.append(label)

    def _flush_updates(self):
        if self._pending_updates > 0 and \
                len(self.replay) >= self.agent.cfg.batch_size:
            self.dispatch_log.append("update")
        super()._flush_updates()

    def run_episode_batch(self, first_episode: int,
                          k: int) -> List[EpisodeRecord]:
        args = self._rollout_args(first_episode, k)
        out = self._rollout(*args)
        self.dispatch_log.append("rollout")
        return self._finish_batch(first_episode, k, out)

    # ------------------------------------------------------- epoch mode
    def _update_schedule(self, first_episode: int,
                         n_batches: int) -> tuple:
        """Per-batch fused-update step counts for an epoch, as a STATIC
        tuple — exactly the budgets ``_queue_updates``/``_flush_updates``
        would dispatch batch by batch. Warmup positions come from the
        episode indices and the replay-fill gate from the host size
        mirror (pushes per batch are fixed at T*K), so the whole
        schedule is known before the dispatch; it must be, because the
        epoch trace derives its update-sampling keys from it."""
        K, T = self.batch_size, len(self.steps)
        cfg = self.agent.cfg
        size, cap = self.replay.size, self.replay.capacity
        sched = []
        for e in range(n_batches):
            warmup, _ = self._batch_schedule(first_episode + e * K, K)
            n = cfg.updates_per_episode * int((~warmup).sum())
            size = min(size + T * K, cap)
            sched.append(n if (n > 0 and size >= cfg.batch_size) else 0)
        return tuple(sched)

    def _epoch_args(self, first_episode: int, n_batches: int) -> tuple:
        """Per-epoch argument tuple for the ``make_epoch_fn`` callable
        (every element stackable across population members). Unlike
        ``_rollout_args`` this does NOT advance the rollout PRNG on the
        host — the scan splits it per batch and the engine adopts the
        final carry."""
        K = self.batch_size
        scheds = [self._batch_schedule(first_episode + e * K, K)
                  for e in range(n_batches)]
        warm = np.stack([w for w, _ in scheds])
        sig = np.stack([s for _, s in scheds])
        keep0, wb0, ab0 = self._ref_rows
        return (self.agent.state_for_dispatch(), self.replay.data,
                self._rollout_key, keep0, wb0, ab0,
                jnp.asarray(sig), jnp.asarray(warm), self.oracle.hwp,
                jnp.asarray(self.tables.shares),
                jnp.asarray(self.tables.ref_total, jnp.float32),
                self._cols,
                jnp.asarray(self.ref_lat.total_s, jnp.float32))

    def _make_epoch_fn(self, schedule: tuple):
        """The pure epoch function for this engine and schedule (the
        population engine vmaps the same construction)."""
        return make_epoch_fn(
            self.agent.cfg, self.cfg.reward, self._rollout_fn,
            self.cmodel.accuracy_policy_fn(self.val_batch),
            len(self.steps), self.batch_size, schedule)

    def _epoch_fn_for(self, schedule: tuple):
        """Compiled epoch executable, FIFO-cached per schedule (steady-
        state epochs all share one schedule => one compilation). Agent
        state and ring buffers are donated: they update in place and the
        pre-dispatch pytrees become invalid — the engine adopts the
        outputs immediately."""
        params = self.cmodel.params
        hit = fifo_cached(
            self._epoch_cache, _EPOCH_CACHE_MAX,
            (self.batch_size, schedule, id(params)),
            lambda h: h[0] is params,
            lambda: (params, jax.jit(self._make_epoch_fn(schedule),
                                     donate_argnums=(0, 1))))
        return hit[1]

    def run_epoch(self, first_episode: int,
                  n_batches: int) -> List[EpisodeRecord]:
        """E episode batches — rollout, validation, reward, ring write,
        updates, metrics — as ONE jit execution, then ONE host readback
        that rehydrates the records in bulk."""
        if n_batches <= 0:
            return []
        self._flush_updates()          # epoch budgets are computed fresh
        schedule = self._update_schedule(first_episode, n_batches)
        fn = self._epoch_fn_for(schedule)
        out = fn(*self._epoch_args(first_episode, n_batches))
        self.dispatch_log.append("epoch")
        return self._finish_epoch(first_episode, n_batches, out)

    def _finish_epoch(self, first_episode: int, n_batches: int,
                      out: tuple) -> List[EpisodeRecord]:
        """Adopt the carried state/ring/PRNG, do the epoch's single
        device->host transfer, and build the records."""
        cfg = self.cfg
        K, T = self.batch_size, len(self.steps)
        st, ring, rkey, best, ys = out
        self.replay.adopt(ring, n_batches * T * K)
        self._rollout_key = rkey
        self.agent.adopt_state(st)
        accs, lats, rewards, keep, wb, ab = ys
        # THE one host readback per epoch: metrics, policies, the norm
        # stats, and the in-carry best — records need no device values
        got = jax.device_get(
            (accs, lats, rewards, keep, wb, ab,
             (st.norm_count, st.norm_mean, st.norm_var),
             (best[0], best[1])))
        accs, lats, rewards, keep, wb, ab, norm, best_hv = got
        self.agent.norm.count = float(norm[0])
        self.agent.norm.mean = np.asarray(norm[1], np.float32)
        self.agent.norm.var = np.asarray(norm[2], np.float32)
        self.last_epoch_best = (first_episode + int(best_hv[1]),
                                float(best_hv[0]))
        denom = cfg.reward.target_ratio * self.ref_lat.total_s
        records = []
        for e in range(n_batches):
            _, sigmas = self._batch_schedule(first_episode + e * K, K)
            pb = PolicyBatch(keep=np.asarray(keep[e], np.float64),
                             w_bits=np.asarray(wb[e], np.float64),
                             a_bits=np.asarray(ab[e], np.float64))
            pols = policies_from_batch(self.specs, pb)
            acc_l, lat_l, rew_l = (
                np.asarray(x, np.float64).tolist()
                for x in (accs[e], lats[e], rewards[e]))
            for j in range(K):
                records.append(EpisodeRecord(
                    episode=first_episode + e * K + j, reward=rew_l[j],
                    accuracy=acc_l[j], latency_s=lat_l[j],
                    latency_ratio=lat_l[j] / denom,
                    macs_frac=pols[j].macs_fraction(self.specs),
                    bops=pols[j].bops(self.specs) if cfg.track_bops
                    else 0.0,
                    sigma=float(sigmas[j]), policy=pols[j]))
        return records

    def _chunk_size(self) -> int:
        if self.epoch_batches > 0:
            return self.batch_size * self.epoch_batches
        return self.batch_size

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        if self.epoch_batches > 0:
            nb, rem = divmod(k, self.batch_size)
            recs = self.run_epoch(first_episode, nb) if nb else []
            if rem:       # trailing partial batch: the per-batch path
                recs += self.run_episode_batch(
                    first_episode + nb * self.batch_size, rem)
            return recs
        return self.run_episode_batch(first_episode, k)


class PopulationSearch:
    """P member searches whose agents share every update dispatch.

    This is the paper's actual workload shape: the p/q/pq agents (and,
    for hardware-specific policies, one member per target) search
    concurrently. Members roll out independently (each already batched
    over K episodes), but their per-chunk update budgets are dispatched
    as ONE ``jit(vmap(update_chunk))`` over the stacked ``AgentState``
    and ``DeviceReplay`` pytrees — P× fewer dispatches on the dominant
    cost of the loop.

    Requirements: members must share one ``DDPGConfig`` (pad
    ``action_dim`` to the population maximum for mixed-method
    populations; see the module docstring) and one chunk size. Members
    whose pending budgets diverge (e.g. different warmup positions)
    fall back to per-member fused flushes for that chunk.

    Construction cost: members built on a common model + calibration
    batch share ONE sensitivity analysis — ``run_sensitivity`` is fused
    (one jit execution for the whole layer×probe grid) and memoized per
    (cmodel, batch, params) identity, so the population constructor
    pays the analysis once, not P times (and rollout fusion requires
    the shared table anyway — see ``_rollouts_fusable``).

    With ``fuse_rollouts=True``, members that are all
    ``FusedCompressionSearch`` over the same specs/sensitivity/context
    with the same methods (hence the same step list — the multi-
    hardware-target scenario, or multiple seeds) additionally share the
    rollout dispatch: one ``jit(vmap(rollout))`` over the stacked agent
    states, policy carries, and per-target ``HwParams``/latency-share
    arguments. Incompatible members silently keep their own (still
    fused) per-member rollout dispatch.
    """

    def __init__(self, members: Sequence[CompressionSearch],
                 fuse_rollouts: bool = False):
        if not members:
            raise ValueError("PopulationSearch needs at least one member")
        self.members = list(members)
        cfg0 = self.members[0].agent.cfg
        for m in self.members[1:]:
            if m.agent.cfg != cfg0:
                raise ValueError(
                    "population members must share a DDPGConfig (pad "
                    f"action_dim): {m.agent.cfg} != {cfg0}")
        if len({m._chunk_size() for m in self.members}) != 1:
            raise ValueError("population members must share a chunk size")
        self.fuse_rollouts = fuse_rollouts
        self._pop_rollout = None
        self._fusable = None
        self._pop_epoch_cache: dict = {}
        self._epoch_fusable = None

    def _stack_for_dispatch(self, trees):
        """Stack per-member pytrees (arg tuples, agent states, rings)
        along a new leading member axis for a shared dispatch.
        ``FleetSearch`` overrides this to pad the member axis up to the
        mesh ``data`` extent and commit the stack to the mesh, which
        makes every shared dispatch run one member per device."""
        return tree_stack(trees)

    def _rollouts_fusable(self) -> bool:
        """One vmapped rollout needs one traced step function: same spec
        list (identity — the oracle/legal/static tables bake into the
        trace), same sensitivity table, same context/window/methods (the
        step lists must coincide), same MXU alignment. Hardware rates
        and latency shares are arguments, so targets may differ."""
        if self._fusable is None:
            ms = self.members
            m0 = ms[0]
            self._fusable = all(isinstance(m, FusedCompressionSearch)
                                for m in ms) and \
                all(m.specs is m0.specs and m.sens is m0.sens
                    and m.ctx == m0.ctx
                    and m.cfg.window == m0.cfg.window
                    and m.cfg.methods == m0.cfg.methods
                    and m.hw.mxu_align == m0.hw.mxu_align
                    and m.calib is m0.calib
                    for m in ms[1:])
        return self._fusable

    def _run_fused_chunk(self, first_episode: int,
                         k: int) -> List[List[EpisodeRecord]]:
        """All members' rollouts as ONE vmapped dispatch, then the
        per-member validation/replay/record tail."""
        args = [m._rollout_args(first_episode, k) for m in self.members]
        stacked = self._stack_for_dispatch(args)
        if self._pop_rollout is None:
            self._pop_rollout = jax.jit(
                jax.vmap(self.members[0]._rollout_fn))
        outs = self._pop_rollout(*stacked)
        for m in self.members:     # ONE shared dispatch, logged on each
            m.dispatch_log.append("rollout")
        return [m._finish_batch(first_episode, k, tree_index(outs, i))
                for i, m in enumerate(self.members)]

    # ------------------------------------------------------- epoch mode
    def _epochs_fusable(self) -> bool:
        """A shared epoch dispatch bakes the validator and the reward
        into one trace on top of the rollout requirements: members must
        share the compressible model, the validation batch, and the
        reward config (the per-target reference-latency scale stays an
        argument) and all run in epoch mode."""
        if self._epoch_fusable is None:
            ms = self.members
            m0 = ms[0]
            self._epoch_fusable = self._rollouts_fusable() and \
                all(getattr(m, "epoch_batches", 0) > 0 for m in ms) and \
                all(m.cmodel is m0.cmodel
                    and m.val_batch is m0.val_batch
                    and m.cfg.reward == m0.cfg.reward for m in ms[1:])
        return self._epoch_fusable

    def run_epoch(self, first_episode: int,
                  n_batches: int) -> List[List[EpisodeRecord]]:
        """All members' epochs as ONE vmapped jit execution — E batches
        x P members of rollout+validate+push+update in a single
        dispatch. Members whose update schedules diverge (they ran
        different histories) fall back to per-member epoch dispatches.
        """
        if n_batches <= 0:
            return [[] for _ in self.members]
        for m in self.members:
            m._flush_updates()
        scheds = {m._update_schedule(first_episode, n_batches)
                  for m in self.members}
        if len(scheds) != 1 or not self._epochs_fusable():
            return [m.run_epoch(first_episode, n_batches)
                    for m in self.members]
        schedule = next(iter(scheds))
        m0 = self.members[0]
        params = m0.cmodel.params
        hit = fifo_cached(
            self._pop_epoch_cache, _EPOCH_CACHE_MAX,
            (m0.batch_size, schedule, id(params)),
            lambda h: h[0] is params,
            lambda: (params,
                     jax.jit(jax.vmap(m0._make_epoch_fn(schedule)),
                             donate_argnums=(0, 1))))
        args = [m._epoch_args(first_episode, n_batches)
                for m in self.members]
        outs = hit[1](*self._stack_for_dispatch(args))
        res = []
        for i, m in enumerate(self.members):
            m.dispatch_log.append("epoch")   # ONE shared dispatch
            res.append(m._finish_epoch(first_episode, n_batches,
                                       tree_index(outs, i)))
        return res

    def _run_epoch_chunk(self, first_episode: int,
                         k: int) -> List[List[EpisodeRecord]]:
        K = self.members[0].batch_size
        nb, rem = divmod(k, K)
        chunks = self.run_epoch(first_episode, nb) if nb \
            else [[] for _ in self.members]
        if rem:           # trailing partial batch: per-batch fused path
            tail = self._run_fused_chunk(first_episode + nb * K, rem)
            chunks = [c + t for c, t in zip(chunks, tail)]
        return chunks

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> List[SearchResult]:
        """Run all members for the same episode count; returns one
        ``SearchResult`` per member, aligned with ``self.members``."""
        n = episodes or min(m.cfg.episodes for m in self.members)
        histories = [[] for _ in self.members]
        bests = [None for _ in self.members]
        saved = [m._defer_updates for m in self.members]
        try:
            for m in self.members:
                m._defer_updates = True
            e = 0
            while e < n:
                k = min(self.members[0]._chunk_size(), n - e)
                if self.fuse_rollouts and self._epochs_fusable():
                    chunks = self._run_epoch_chunk(e, k)
                elif self.fuse_rollouts and self._rollouts_fusable() \
                        and k <= self.members[0].batch_size:
                    chunks = self._run_fused_chunk(e, k)
                else:
                    # epoch members whose epochs can't share one trace
                    # keep their own per-member epoch decomposition
                    chunks = [m._run_chunk(e, k) for m in self.members]
                for i, recs in enumerate(chunks):
                    for rec in recs:
                        histories[i].append(rec)
                        if bests[i] is None or rec.reward > bests[i].reward:
                            bests[i] = rec
                self._dispatch_updates()
                if verbose:
                    last = e + k - 1
                    row = " ".join(
                        f"{m.cfg.methods}:{histories[i][-1].reward:+.3f}"
                        for i, m in enumerate(self.members))
                    print(f"  ep {last:4d} rewards [{row}]")
                e += k
        finally:
            for m, flag in zip(self.members, saved):
                m._defer_updates = flag
        return [SearchResult(history=histories[i], best=bests[i],
                             ref_latency_s=m.ref_lat.total_s,
                             ref_accuracy=m.ref_acc)
                for i, m in enumerate(self.members)]

    def _dispatch_updates(self):
        """One vmapped chunk for the whole population when the members'
        budgets agree; per-member fused flushes otherwise."""
        ns = [m._pending_updates for m in self.members]
        ready = all(len(m.replay) >= m.agent.cfg.batch_size
                    for m in self.members)
        if len(set(ns)) == 1 and ns[0] > 0 and ready:
            n = ns[0]
            states = self._stack_for_dispatch(
                [m.agent.state_for_dispatch() for m in self.members])
            datas = self._stack_for_dispatch(
                [m.replay.data for m in self.members])
            # states are freshly stacked and never reused after the
            # call, so the megabatched path may donate them in place
            new_states, _losses = population_update_chunk(
                self.members[0].agent.cfg, states, datas, n, donate=True)
            for i, m in enumerate(self.members):
                m.agent.adopt_state(tree_index(new_states, i))
                m._pending_updates = 0
                if isinstance(m, FusedCompressionSearch):
                    m.dispatch_log.append("update")   # shared dispatch
        else:
            for m in self.members:
                m._flush_updates()


class FleetSearch(PopulationSearch):
    """Mesh-sharded population search with preemption-safe epoch
    checkpoints — the "search-as-a-service" driver.

    ``PopulationSearch`` already runs the whole population's epoch as ONE
    ``jit(vmap(epoch))`` over stacked per-member carries, but the stack
    lives on one device, so P members time-slice it. ``FleetSearch``
    commits every stacked dispatch operand to a device mesh with
    ``NamedSharding(mesh, P("data"))`` along the member axis
    (``_stack_for_dispatch``): the SAME program then executes one member
    per device (members beyond the ``data`` extent round-robin; the stack
    is padded up to a multiple of it by repeating the last member, whose
    extra outputs are discarded). Per-member math never mixes member
    rows, so the partitioned program contains no collectives.

    Preemption safety: every ``ckpt_every`` completed epochs the stacked
    carry — ``AgentState``, ``DeviceReplay`` ring, rollout PRNG key per
    member — is checkpointed through the atomic async writer
    (``checkpoint.checkpointing.save_async``); the manifest records the
    mesh shape, the epoch cursor, per-member seeds/methods, and the ring
    ptr/size mirrors. ``restore_latest_checkpoint`` re-shards the carry
    onto the *current* mesh — including a smaller one after device loss
    (``fault_tolerance.elastic_data_axis`` picks the data extent the
    survivors support) — and the next ``run_fleet`` call resumes from the
    restored cursor. On the same mesh the resume is bit-exact: the carry
    holds every PRNG stream and the update schedule is a pure function of
    (episode cursor, restored ring size). A ``StepMonitor`` times each
    epoch dispatch and flags stragglers (``monitor.summary()``).

    ``mesh=None`` degrades to plain single-device ``PopulationSearch``
    dispatch while keeping the checkpoint/resume machinery — the fleet
    semantics are mesh-size independent by construction.
    """

    def __init__(self, members: Sequence[CompressionSearch], mesh=None,
                 fuse_rollouts: bool = True, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 1, keep: int = 3,
                 ft_cfg: Optional[FaultToleranceConfig] = None):
        super().__init__(members, fuse_rollouts=fuse_rollouts)
        for m in self.members:
            if getattr(m, "epoch_batches", 0) <= 0:
                raise ValueError(
                    "FleetSearch members must be FusedCompressionSearch "
                    "in epoch mode (epoch_batches > 0)")
        if not self._epochs_fusable():
            raise ValueError(
                "FleetSearch members must share one epoch trace (same "
                "specs/sensitivity/context/methods/model/reward — vary "
                "seeds or hardware targets instead)")
        if mesh is not None and "data" not in mesh.axis_names:
            raise ValueError(
                f"FleetSearch mesh needs a 'data' axis to shard the "
                f"member dimension; got axes {mesh.axis_names}")
        self.mesh = mesh
        self.monitor = StepMonitor(ft_cfg or FaultToleranceConfig())
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = max(1, int(ckpt_every))
        self._ckpt = AsyncCheckpointer(ckpt_dir, keep=keep) \
            if ckpt_dir else None
        self.epoch_cursor = 0      # episodes completed (per member)
        self.epochs_run = 0        # epoch dispatches completed

    # ------------------------------------------------------ mesh placement
    def _stack_for_dispatch(self, trees):
        if self.mesh is None:
            return tree_stack(trees)
        stacked = tree_stack(pad_members(list(trees),
                                         self.mesh.shape["data"]))
        return jax.device_put(stacked,
                              population_shardings(stacked, self.mesh))

    # ------------------------------------------------------- checkpointing
    def _fleet_carry(self) -> dict:
        """The checkpointable stacked epoch carry. ``state_for_dispatch``
        folds the host-side norm/reward-MA mirrors into the pytree first,
        so the checkpoint is self-contained."""
        return {
            "agent": tree_stack([m.agent.state_for_dispatch()
                                 for m in self.members]),
            "ring": tree_stack([m.replay.data for m in self.members]),
            "rollout_key": jnp.stack([m._rollout_key
                                      for m in self.members]),
        }

    def _manifest_extra(self) -> dict:
        return {
            "epoch_cursor": int(self.epoch_cursor),
            "epochs_run": int(self.epochs_run),
            "mesh_shape": dict(self.mesh.shape)
            if self.mesh is not None else None,
            "member_seeds": [int(m.cfg.seed) for m in self.members],
            "member_methods": [m.cfg.methods for m in self.members],
            "ring_ptr": [int(m.replay.ptr) for m in self.members],
            "ring_size": [int(m.replay.size) for m in self.members],
            "monitor": self.monitor.summary(),
        }

    def save_checkpoint(self, wait: bool = False):
        """Atomic async save of the stacked carry (one step per completed
        epoch). The snapshot happens now; the write runs in the
        background and the previous checkpoint stays intact until the new
        LATEST pointer lands."""
        if self._ckpt is None:
            raise ValueError("FleetSearch was built without ckpt_dir")
        save_async(self._ckpt, self.epochs_run, self._fleet_carry(),
                   self._manifest_extra())
        if wait:
            self._ckpt.wait()

    def restore_latest_checkpoint(self, directory: Optional[str] = None):
        """Restore the newest intact checkpoint and re-shard the carry
        onto the CURRENT mesh (which may be smaller than the one that
        saved it — elastic resume). Returns the manifest extra, or None
        when no checkpoint exists. On the same mesh shape the subsequent
        ``run_fleet`` continuation is bit-exact."""
        directory = directory or self.ckpt_dir
        if directory is None:
            raise ValueError("no checkpoint directory given")
        like = self._fleet_carry()
        shardings = None
        if self.mesh is not None and \
                len(self.members) % self.mesh.shape["data"] == 0:
            # direct re-shard; a non-dividing member count is placed by
            # the next _stack_for_dispatch (which pads) instead
            shardings = population_shardings(like, self.mesh)
        tree, step, extra = restore_latest(directory, like, shardings)
        if tree is None:
            return None
        P = len(self.members)
        if len(extra.get("member_seeds", [])) != P:
            raise ValueError(
                f"checkpoint holds {len(extra.get('member_seeds', []))} "
                f"members, fleet has {P}")
        for i, m in enumerate(self.members):
            st = tree_index(tree["agent"], i)
            m.agent.adopt_state(st)
            norm = jax.device_get((st.norm_count, st.norm_mean,
                                   st.norm_var))
            m.agent.norm.count = float(norm[0])
            m.agent.norm.mean = np.asarray(norm[1], np.float32)
            m.agent.norm.var = np.asarray(norm[2], np.float32)
            m.replay.load(tree_index(tree["ring"], i),
                          extra["ring_ptr"][i], extra["ring_size"][i])
            m._rollout_key = tree["rollout_key"][i]
        self.epoch_cursor = int(extra["epoch_cursor"])
        self.epochs_run = int(extra["epochs_run"])
        return extra

    # --------------------------------------------------------- fleet loop
    def run_fleet(self, episodes: int,
                  verbose: bool = False) -> List[SearchResult]:
        """Run whole fleet epochs from ``self.epoch_cursor`` (0, or the
        restored checkpoint's cursor) until ``episodes`` total episodes
        per member, checkpointing every ``ckpt_every`` epochs. Histories
        cover only the episodes run by THIS call — a resumed fleet
        returns the post-restore tail, which is what resume parity tests
        compare."""
        K = self.members[0].batch_size
        E = self.members[0].epoch_batches
        if episodes % K:
            raise ValueError(
                f"episodes ({episodes}) must be a multiple of the "
                f"episode batch size ({K}) — fleets run whole batches")
        histories = [[] for _ in self.members]
        bests: List[Optional[EpisodeRecord]] = [None] * len(self.members)
        while self.epoch_cursor < episodes:
            nb = min(E, (episodes - self.epoch_cursor) // K)
            t0 = time.perf_counter()
            # run_epoch ends with the epoch's single blocking host
            # readback, so this wall time covers the full dispatch
            chunks = self.run_epoch(self.epoch_cursor, nb)
            self.epochs_run += 1
            self.monitor.record(self.epochs_run,
                                time.perf_counter() - t0)
            self.epoch_cursor += nb * K
            for i, recs in enumerate(chunks):
                for rec in recs:
                    histories[i].append(rec)
                    if bests[i] is None or rec.reward > bests[i].reward:
                        bests[i] = rec
            if self._ckpt is not None and \
                    self.epochs_run % self.ckpt_every == 0:
                self.save_checkpoint()
            if verbose:
                row = " ".join(
                    f"{m.cfg.methods}:{histories[i][-1].reward:+.3f}"
                    for i, m in enumerate(self.members))
                print(f"  epoch {self.epochs_run:4d} "
                      f"ep {self.epoch_cursor:5d} rewards [{row}]")
        if self._ckpt is not None:
            self._ckpt.wait()
        return [SearchResult(history=histories[i], best=bests[i],
                             ref_latency_s=m.ref_lat.total_s,
                             ref_accuracy=m.ref_acc)
                for i, m in enumerate(self.members)]
