"""The Galen search loop (paper Fig. 1/2): episodes of layer-wise policy
prediction, hardware-oracle validation, and DDPG optimization.

Three agents (paper §Proposed Agents) share this loop and differ only in
``methods``:  "p" (pruning), "q" (quantization), "pq" (joint).

How the episode engines work
----------------------------
Three engines share the per-episode semantics (sigma decay schedule,
warmup flags, shared-episode-reward transition scheme, hardware
legality) and differ only in how much of an episode batch runs per
host dispatch:

* ``CompressionSearch.run_episode`` — the scalar reference path: walk
  the actionable units in order, build the agent state (which probes
  the analytic latency oracle under the partial policy), act, map the
  continuous action to a legal CMP, then validate the finished policy
  (one jitted accuracy eval + one oracle call) and push the transitions
  with the shared episode reward.

* ``BatchedCompressionSearch`` — K episodes per rollout, still L host
  steps: ``build_state_batch`` + one vectorized numpy oracle call per
  layer step, ``DDPGAgent.act_batch`` (host numpy actor), a Python
  ``map_actions`` loop over the K episodes, then one fused
  cspec+accuracy jit call and a single bulk ring write.

* ``FusedCompressionSearch`` — the whole K-episode rollout is ONE
  ``jit(lax.scan)`` over the layer steps: a traceable ``JaxBatchOracle``
  builds the latency features, ``agent_act_batch`` runs the actor (with
  in-scan PRNG for warmup/sigma exploration), ``map_actions_batch``
  projects actions to legal CMPs as array ops, and the (K, L) policy
  arrays live in the scan carry. Validation and learning then reuse the
  fused paths (``accuracy_policy_batch`` + ``update_chunk``).

Cost per episode batch (K episodes over L actionable units,
post-compile; u = fused update-chunk dispatches):

  ========  ====================  ===========================
  engine    host environment      jit dispatches
            steps per batch       per batch
  ========  ====================  ===========================
  scalar    K * L                 2K + u   (accuracy + ring
                                  write per episode)
  batched   L                     2 + u    (fused validation
                                  + one bulk ring write)
  fused     0                     3 + u    (<= 4 total)
  ========  ====================  ===========================

A "host environment step" is one oracle probe + state build + actor
forward + action->CMP mapping round-trip on the host; the fused
engine's three dispatches are rollout, validation, and the replay ring
write (its ``dispatch_log`` records them so benchmarks can assert the
count never regresses). The numpy engines stay as the parity
references — ``tests/test_fused.py`` property-tests the fused rollout
against ``BatchedCompressionSearch`` step for step.

Where the learning happens (PR 2: the functional agent core)
-----------------------------------------------------------
Both engines store transitions in a device-resident ``DeviceReplay``
(``core/replay.py``) and dispatch *all* of an episode batch's critic/
actor/target updates as ONE jitted ``lax.scan`` —
``DDPGAgent.update_chunk`` over the ``AgentState`` pytree
(``core/ddpg.py``). Replay sampling, reward moving-average centering,
state standardization, and the Adam/soft-target math all run inside the
scan; the only host sync per episode batch is the loss array. The
scalar engine fuses its ``updates_per_episode`` steps the same way, so
the two paths differ only in rollout batching.

``PopulationSearch`` stacks P member searches (p/q/pq agents, multiple
seeds, or one member per hardware target) and replaces their P separate
update dispatches with one ``jit(vmap(update_chunk))`` over the stacked
``AgentState``/replay pytrees. Members with different native action
dimensionalities share one population by padding ``action_dim`` to the
maximum (``map_actions`` consumes a prefix of the action vector, so
trailing entries are inert for single-method agents). With
``fuse_rollouts=True`` and ``FusedCompressionSearch`` members that
share a step list (same methods — e.g. one member per hardware target,
whose rate parameters enter the traced oracle as a vmappable
``HwParams`` pytree), the P rollout dispatches also collapse into one
``jit(vmap(rollout))``.

Semantic notes, both at batch granularity: critic/actor updates for the
K episodes of a batch run after the whole batch (same total update
count) rather than interleaved between episodes, and the state
normalizer's running stats advance once per batch, so episodes within a
batch act on the stats from the previous batch boundary. Within an
update chunk the normalizer snapshot is frozen and the reward moving
average advances per step — exactly the scalar ``DDPGAgent.update``
semantics, property-tested in ``tests/test_agent_core.py``.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import legal_tables
from repro.core.ddpg import (DDPGAgent, DDPGConfig, agent_act_batch,
                             population_update_chunk, tree_index, tree_stack)
from repro.core.latency import (V5E, HardwareTarget, LatencyContext,
                                get_jax_oracle, policy_latency,
                                policy_latency_batch)
from repro.core.policy import (Policy, PolicyBatch, action_columns,
                               map_actions, map_actions_batch, n_actions,
                               policies_from_batch, stack_policies)
from repro.core.replay import DeviceReplay
from repro.core.reward import RewardConfig, compute_reward, \
    compute_reward_batch
from repro.core.sensitivity import SensitivityResult, run_sensitivity
from repro.core.spec import effective_bits
from repro.core.state import (StateTables, build_state, build_state_batch,
                              fused_state_block, state_dim)


@dataclass(frozen=True)
class SearchConfig:
    methods: str = "pq"                # p | q | pq
    episodes: int = 120
    reward: RewardConfig = field(default_factory=RewardConfig)
    ddpg: Optional[DDPGConfig] = None  # None -> sized to the method set
    seed: int = 0
    window: int = 0                    # attention window for the oracle
    track_bops: bool = True


@dataclass
class EpisodeRecord:
    episode: int
    reward: float
    accuracy: float
    latency_s: float
    latency_ratio: float
    macs_frac: float
    bops: float
    sigma: float
    policy: Policy = field(repr=False, default=None)


@dataclass
class SearchResult:
    history: List[EpisodeRecord]
    best: EpisodeRecord
    ref_latency_s: float
    ref_accuracy: float

    def best_under_budget(self, tol: float = 0.05) -> Optional[EpisodeRecord]:
        c = None
        for r in self.history:
            if r.latency_ratio <= (1.0 + tol):
                if c is None or r.accuracy > c.accuracy:
                    c = r
        return c


def _actionable(spec, methods: str) -> bool:
    if methods == "p":
        return spec.prunable and spec.prune_dim > 0
    if methods == "q":
        return spec.quantizable
    return spec.quantizable or (spec.prunable and spec.prune_dim > 0)


class CompressionSearch:
    """Owns: the compressible model, the sensitivity table, the latency
    oracle context, the agent, and the episode loop."""

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None):
        self.cmodel = cmodel
        self.specs = cmodel.specs
        self.cfg = search_cfg
        self.hw = hw
        self.ctx = ctx
        self.val_batch = val_batch
        native = n_actions(search_cfg.methods)
        ddpg_cfg = search_cfg.ddpg or DDPGConfig(
            state_dim=state_dim(native), action_dim=native)
        # a provided action_dim larger than the method's native one pads
        # the action space (population members must share shapes); a
        # smaller one is corrected up to native
        a_dim = max(native, ddpg_cfg.action_dim)
        if (ddpg_cfg.state_dim, ddpg_cfg.action_dim) != (state_dim(a_dim),
                                                         a_dim):
            ddpg_cfg = DDPGConfig(**{**ddpg_cfg.__dict__,
                                     "state_dim": state_dim(a_dim),
                                     "action_dim": a_dim})
        self.agent = DDPGAgent(ddpg_cfg, seed=search_cfg.seed)
        self.replay = DeviceReplay(ddpg_cfg.buffer_size, ddpg_cfg.state_dim,
                                   a_dim, seed=search_cfg.seed)
        self.sens = sens if sens is not None else run_sensitivity(
            cmodel, calib_batch if calib_batch is not None else val_batch)
        self._jit_acc = jax.jit(lambda cs: cmodel.accuracy(val_batch, cs))
        self.ref_policy = Policy.reference(self.specs)
        self.ref_lat = policy_latency(self.specs, self.ref_policy, hw, ctx,
                                      search_cfg.window)
        self.ref_acc = float(self._jit_acc(
            cmodel.build_cspec(self.ref_policy)))
        self.steps = [i for i, s in enumerate(self.specs)
                      if _actionable(s, search_cfg.methods)]
        self._pending_updates = 0
        self._defer_updates = False     # PopulationSearch batches flushes

    # ------------------------------------------------------------------
    def _flush_updates(self):
        """Dispatch the accumulated update budget as one fused chunk."""
        n = self._pending_updates
        self._pending_updates = 0
        if n > 0 and len(self.replay) >= self.agent.cfg.batch_size:
            self.agent.update_chunk(self.replay, n)

    def _queue_updates(self, n: int):
        self._pending_updates += n
        if not self._defer_updates:
            self._flush_updates()

    # ------------------------------------------------------------------
    def run_episode(self, episode: int) -> EpisodeRecord:
        cfg = self.cfg
        warmup = episode < self.agent.cfg.warmup_episodes
        sigma = self.agent.sigma_at(episode)
        partial = copy.deepcopy(self.ref_policy)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros(a_dim, np.float32)
        states, actions = [], []
        for t in self.steps:
            s_vec = build_state(self.specs, t, partial, self.sens, prev_a,
                                self.hw, self.ctx, self.ref_lat, cfg.window)
            a = self.agent.act(s_vec, sigma, random=warmup)
            cmp = map_actions(self.specs[t], a, cfg.methods)
            # single-method agents preserve the other method's parameters
            # from the reference policy (supports the sequential scheme:
            # a frozen stage-1 policy as the starting point, paper App. A)
            prev = partial.cmps[t]
            if cfg.methods == "q":
                cmp.keep = prev.keep
            elif cfg.methods == "p":
                cmp.mode, cmp.w_bits, cmp.a_bits = (prev.mode, prev.w_bits,
                                                    prev.a_bits)
            partial.cmps[t] = cmp
            states.append(s_vec)
            actions.append(a)
            prev_a = a
        policy = partial

        cspec = self.cmodel.build_cspec(policy)
        acc = float(self._jit_acc(cspec))
        lat = policy_latency(self.specs, policy, self.hw, self.ctx,
                             cfg.window)
        reward = compute_reward(cfg.reward, acc, lat.total_s,
                                self.ref_lat.total_s)
        # push transitions — one shared episode reward (paper §Schema),
        # one bulk ring write for the whole chain
        T = len(states)
        st_arr = np.stack(states)
        self.agent.observe_states(st_arr)
        nxt = np.concatenate([st_arr[1:], st_arr[-1:]])
        done = np.zeros(T, np.float32)
        done[-1] = 1.0
        self.replay.push_batch(st_arr, np.stack(actions),
                               np.full(T, reward, np.float32), nxt, done)
        if not warmup:
            self._queue_updates(self.agent.cfg.updates_per_episode)

        ratio = lat.total_s / (cfg.reward.target_ratio *
                               self.ref_lat.total_s)
        return EpisodeRecord(
            episode=episode, reward=reward, accuracy=acc,
            latency_s=lat.total_s, latency_ratio=ratio,
            macs_frac=policy.macs_fraction(self.specs),
            bops=policy.bops(self.specs) if cfg.track_bops else 0.0,
            sigma=sigma, policy=policy)

    # chunking hooks: the scalar engine advances one episode at a time;
    # BatchedCompressionSearch overrides these to roll K per call
    def _chunk_size(self) -> int:
        return 1

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return [self.run_episode(first_episode)]

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> SearchResult:
        n = episodes or self.cfg.episodes
        history: List[EpisodeRecord] = []
        best = None
        e = 0
        while e < n:
            k = min(self._chunk_size(), n - e)
            for rec in self._run_chunk(e, k):
                history.append(rec)
                if best is None or rec.reward > best.reward:
                    best = rec
                if verbose and (rec.episode % 10 == 0
                                or rec.episode == n - 1):
                    print(f"  ep {rec.episode:4d} reward={rec.reward:+.4f} "
                          f"acc={rec.accuracy:.3f} "
                          f"lat_ratio={rec.latency_ratio:.3f} "
                          f"sigma={rec.sigma:.3f}")
            e += k
        return SearchResult(history=history, best=best,
                            ref_latency_s=self.ref_lat.total_s,
                            ref_accuracy=self.ref_acc)


class BatchedCompressionSearch(CompressionSearch):
    """K episodes per rollout; see the module docstring for the engine.

    Per-episode semantics (sigma schedule, warmup, shared episode
    reward, legality constraints) match ``CompressionSearch``; only the
    dispatch is amortized, so episode throughput scales with K.
    """

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, batch_size: int = 8):
        super().__init__(cmodel, val_batch, search_cfg, ctx, hw=hw,
                         sens=sens, calib_batch=calib_batch)
        self.batch_size = max(1, batch_size)

    # ------------------------------------------------------------------
    def _batch_schedule(self, first_episode: int, k: int):
        """(warmup mask, sigma) per episode row — THE one place the
        batch's exploration schedule is derived (rollout and
        finish/record paths must agree on it)."""
        eps = range(first_episode, first_episode + k)
        warmup = np.asarray(
            [e < self.agent.cfg.warmup_episodes for e in eps])
        sigmas = np.asarray([self.agent.sigma_at(e) for e in eps],
                            np.float32)
        return warmup, sigmas

    def run_episode_batch(self, first_episode: int,
                          k: int) -> List[EpisodeRecord]:
        cfg = self.cfg
        eps = list(range(first_episode, first_episode + k))
        warmup, sigmas = self._batch_schedule(first_episode, k)
        partials = [copy.deepcopy(self.ref_policy) for _ in eps]
        # (K, L) policy arrays, updated in place as units are decided
        pb = stack_policies(self.specs, partials)
        a_dim = self.agent.cfg.action_dim
        prev_a = np.zeros((k, a_dim), np.float32)
        step_states, step_actions = [], []
        for t in self.steps:
            cur = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                       cfg.window)
            S = build_state_batch(self.specs, t, cur, self.sens, prev_a,
                                  self.ref_lat)
            A = self.agent.act_batch(S, sigmas, warmup)
            for j in range(k):
                cmp = map_actions(self.specs[t], A[j], cfg.methods)
                prev = partials[j].cmps[t]
                if cfg.methods == "q":
                    cmp.keep = prev.keep
                elif cfg.methods == "p":
                    cmp.mode, cmp.w_bits, cmp.a_bits = (
                        prev.mode, prev.w_bits, prev.a_bits)
                partials[j].cmps[t] = cmp
                pb.keep[j, t] = cmp.keep
                pb.w_bits[j, t], pb.a_bits[j, t] = effective_bits(cmp)
            step_states.append(S)
            step_actions.append(A)
            prev_a = A

        # --- batched validation: one fused cspec+accuracy jit call and
        # one vectorized oracle call for the whole batch
        accs = np.asarray(
            self.cmodel.accuracy_policy_batch(self.val_batch, pb))
        lats = policy_latency_batch(self.specs, pb, self.hw, self.ctx,
                                    cfg.window).total_s
        rewards = np.asarray([
            compute_reward(cfg.reward, float(accs[j]), float(lats[j]),
                           self.ref_lat.total_s) for j in range(k)])
        return self._push_and_record(
            eps, warmup, sigmas, partials, np.stack(step_states),
            np.stack(step_actions), accs, lats, rewards)

    def _log_dispatch(self, label: str):
        """Hook for engines that account their jit dispatches (the
        fused engine's ``dispatch_log``); no-op here."""

    def _push_and_record(self, eps, warmup, sigmas, pols, states,
                         actions, accs, lats,
                         rewards) -> List[EpisodeRecord]:
        """The engines' shared batch tail — THE definition of the
        shared-episode-reward transition scheme: observe the (T, K, ·)
        states, push per-episode chains as one bulk ring write
        (reward repeated along each chain, done on the last step),
        queue the live episodes' update budget, and build the records.
        """
        cfg = self.cfg
        T, k = len(self.steps), len(eps)
        self.agent.observe_states(states.reshape(T * k, -1))
        nxt = np.concatenate([states[1:], states[-1:]])
        done = np.zeros((T, k), np.float32)
        done[-1] = 1.0
        order = lambda x: x.swapaxes(0, 1).reshape(T * k, *x.shape[2:])
        self.replay.push_batch(
            order(states), order(actions),
            np.repeat(rewards, T).astype(np.float32),
            order(nxt), order(done))
        self._log_dispatch("push")
        n_live = int((~warmup).sum())
        self._queue_updates(self.agent.cfg.updates_per_episode * n_live)

        records = []
        for j, e in enumerate(eps):
            ratio = float(lats[j]) / (cfg.reward.target_ratio *
                                      self.ref_lat.total_s)
            records.append(EpisodeRecord(
                episode=e, reward=float(rewards[j]),
                accuracy=float(accs[j]), latency_s=float(lats[j]),
                latency_ratio=ratio,
                macs_frac=pols[j].macs_fraction(self.specs),
                bops=pols[j].bops(self.specs) if cfg.track_bops else 0.0,
                sigma=float(sigmas[j]), policy=pols[j]))
        return records

    def _chunk_size(self) -> int:
        return self.batch_size

    def _run_chunk(self, first_episode: int,
                   k: int) -> List[EpisodeRecord]:
        return self.run_episode_batch(first_episode, k)


# ===========================================================================
# Fused engine: the rollout environment as one jit(lax.scan)
# ===========================================================================

class MethodCols(NamedTuple):
    """Which action columns feed pruning/quantization, and whether each
    method is live — as traced values, so the rollout step function is
    method-agnostic (one compiled form serves p/q/pq and the columns
    vmap across a population)."""
    ip: jnp.ndarray            # () i32  prune-ratio action column
    iw: jnp.ndarray            # () i32  weight-bits action column
    ia: jnp.ndarray            # () i32  act-bits action column
    do_p: jnp.ndarray          # () bool method prunes
    do_q: jnp.ndarray          # () bool method quantizes


def method_cols(methods: str) -> MethodCols:
    ip, iw, ia = action_columns(methods)
    return MethodCols(
        ip=jnp.asarray(ip, jnp.int32), iw=jnp.asarray(iw, jnp.int32),
        ia=jnp.asarray(ia, jnp.int32),
        do_p=jnp.asarray("p" in methods), do_q=jnp.asarray("q" in methods))


def make_rollout_fn(cfg: DDPGConfig, oracle, legal, static_tab, spec_steps):
    """Build the pure rollout function the fused engine jits (and the
    population engine ``jit(vmap)``s).

    Closure constants: the agent config, the traceable oracle (specs/
    context tables; hardware rates stay in the ``hwp`` argument), the
    legality tables, the (T, S) static feature rows, and the (T,) spec
    index per step. Everything hardware- or member-specific is an
    argument so one traced function serves a vmapped stack of members.

    Returns ``rollout(st, keep0, wb0, ab0, sigmas, warmup, hwp, shares,
    ref_total, cols, keys) -> (keep, wb, ab, states, actions, lats)``
    with ``states``/``actions`` stacked (T, K, ·) in step order and
    ``lats`` the final policies' oracle latency — the whole episode
    environment in one dispatch.
    """
    pd = jnp.asarray(legal.prune_dim)
    gran = jnp.asarray(legal.granularity)
    prunable = jnp.asarray(legal.prunable)
    quantizable = jnp.asarray(legal.quantizable)
    mix_ok = jnp.asarray(legal.mix_ok)
    static_tab = jnp.asarray(static_tab)
    spec_steps = jnp.asarray(spec_steps)

    def rollout(st, keep0, wb0, ab0, sigmas, warmup, hwp, shares,
                ref_total, cols, keys):
        K = sigmas.shape[0]
        L = keep0.shape[-1]
        init = (jnp.broadcast_to(keep0, (K, L)),
                jnp.broadcast_to(wb0, (K, L)),
                jnp.broadcast_to(ab0, (K, L)),
                jnp.zeros((K, cfg.action_dim), jnp.float32))

        def step(carry, x):
            keep, wb, ab, prev_a = carry
            t, static_row, share_row, k = x
            unit_t, extra_t = oracle.unit_times(keep, wb, ab, hwp)
            decided = oracle.decided_before(unit_t, extra_t, t) / ref_total
            S = fused_state_block(static_row, share_row, decided, prev_a)
            A = agent_act_batch(cfg, st, S, k, sigmas, warmup)
            new_keep, new_wb, new_ab = map_actions_batch(
                A, prune_dim=pd[t], granularity=gran[t],
                prunable=prunable[t], quantizable=quantizable[t],
                mix_ok=mix_ok[t], ip=cols.ip, iw=cols.iw, ia=cols.ia)
            # single-method agents preserve the other method's reference
            # parameters (same rule as the host engines)
            keep = keep.at[:, t].set(
                jnp.where(cols.do_p, new_keep, keep[:, t]))
            wb = wb.at[:, t].set(jnp.where(cols.do_q, new_wb, wb[:, t]))
            ab = ab.at[:, t].set(jnp.where(cols.do_q, new_ab, ab[:, t]))
            return (keep, wb, ab, A), (S, A)

        xs = (spec_steps, static_tab, shares, keys)
        (keep, wb, ab, _), (states, actions) = jax.lax.scan(step, init, xs)
        unit_t, extra_t = oracle.unit_times(keep, wb, ab, hwp)
        lats = oracle.totals(unit_t, extra_t, hwp)
        return keep, wb, ab, states, actions, lats

    return rollout


class FusedCompressionSearch(BatchedCompressionSearch):
    """K episodes per rollout, the rollout itself ONE jit dispatch.

    Same per-episode semantics as the numpy engines; the environment
    (oracle features, actor, action->CMP projection, policy carry) runs
    as a ``lax.scan`` over the layer steps, so an episode batch costs
    rollout + validation + ring write + update chunk — at most 4 jit
    executions — instead of ~2L host dispatches. ``dispatch_log``
    records each fused-path dispatch ("rollout"/"validate"/"push"/
    "update"); the weekly benchmark cross-checks it against measured
    invocations of the compiled entry points
    (``benchmarks.search_setup.fused_dispatch_probe``). In a fused
    population, dispatches shared across members (rollout, update)
    appear in every member's log.

    Exploration randomness comes from a dedicated jax PRNG stream
    (``seed``-derived, separate from the agent's update-sampling key);
    ``_last_batch_key`` exposes the per-batch key so parity tests can
    replay the exact draws through the numpy reference engine.
    """

    def __init__(self, cmodel, val_batch, search_cfg: SearchConfig,
                 ctx: LatencyContext, hw: HardwareTarget = V5E,
                 sens: Optional[SensitivityResult] = None,
                 calib_batch=None, batch_size: int = 8):
        super().__init__(cmodel, val_batch, search_cfg, ctx, hw=hw,
                         sens=sens, calib_batch=calib_batch,
                         batch_size=batch_size)
        self.oracle = get_jax_oracle(self.specs, hw, ctx, search_cfg.window)
        self.tables = StateTables(self.specs, self.steps, self.sens,
                                  self.ref_lat)
        ref_pb = stack_policies(self.specs, [self.ref_policy])
        self._ref_rows = tuple(
            jnp.asarray(x[0], jnp.float32)
            for x in (ref_pb.keep, ref_pb.w_bits, ref_pb.a_bits))
        self._cols = method_cols(search_cfg.methods)
        self._rollout_fn = make_rollout_fn(
            self.agent.cfg, self.oracle, legal_tables(self.specs),
            self.tables.static, self.tables.spec_idx)
        self._rollout = jax.jit(self._rollout_fn)
        self._rollout_key = jax.random.PRNGKey(search_cfg.seed + 0x5EED)
        self._last_batch_key = None
        self.dispatch_log: List[str] = []

    # ------------------------------------------------------------------
    def _rollout_args(self, first_episode: int, k: int) -> tuple:
        """Per-batch argument tuple for ``_rollout_fn`` (every element
        stackable across population members); advances the rollout PRNG
        stream."""
        warmup, sigmas = self._batch_schedule(first_episode, k)
        self._rollout_key, bk = jax.random.split(self._rollout_key)
        self._last_batch_key = bk
        keys = jax.random.split(bk, len(self.steps))
        keep0, wb0, ab0 = self._ref_rows
        return (self.agent.state_for_dispatch(), keep0, wb0, ab0,
                jnp.asarray(sigmas), jnp.asarray(warmup), self.oracle.hwp,
                jnp.asarray(self.tables.shares),
                jnp.asarray(self.tables.ref_total, jnp.float32),
                self._cols, keys)

    def _finish_batch(self, first_episode: int, k: int,
                      out: tuple) -> List[EpisodeRecord]:
        """Validation, reward, replay write, records — everything after
        the rollout dispatch. ``out`` is a ``_rollout_fn`` result."""
        cfg = self.cfg
        keep, wb, ab, dev_states, dev_actions, lats = out
        eps = list(range(first_episode, first_episode + k))
        warmup, sigmas = self._batch_schedule(first_episode, k)
        pb = PolicyBatch(keep=np.asarray(keep, np.float64),
                         w_bits=np.asarray(wb, np.float64),
                         a_bits=np.asarray(ab, np.float64))
        accs = np.asarray(
            self.cmodel.accuracy_policy_batch(self.val_batch, pb))
        self.dispatch_log.append("validate")
        lats = np.asarray(lats, np.float64)
        rewards = np.asarray(compute_reward_batch(
            cfg.reward, accs.astype(np.float32),
            lats.astype(np.float32), self.ref_lat.total_s), np.float64)
        return self._push_and_record(
            eps, warmup, sigmas, policies_from_batch(self.specs, pb),
            np.asarray(dev_states), np.asarray(dev_actions), accs, lats,
            rewards)

    def _log_dispatch(self, label: str):
        self.dispatch_log.append(label)

    def _flush_updates(self):
        if self._pending_updates > 0 and \
                len(self.replay) >= self.agent.cfg.batch_size:
            self.dispatch_log.append("update")
        super()._flush_updates()

    def run_episode_batch(self, first_episode: int,
                          k: int) -> List[EpisodeRecord]:
        args = self._rollout_args(first_episode, k)
        out = self._rollout(*args)
        self.dispatch_log.append("rollout")
        return self._finish_batch(first_episode, k, out)


class PopulationSearch:
    """P member searches whose agents share every update dispatch.

    This is the paper's actual workload shape: the p/q/pq agents (and,
    for hardware-specific policies, one member per target) search
    concurrently. Members roll out independently (each already batched
    over K episodes), but their per-chunk update budgets are dispatched
    as ONE ``jit(vmap(update_chunk))`` over the stacked ``AgentState``
    and ``DeviceReplay`` pytrees — P× fewer dispatches on the dominant
    cost of the loop.

    Requirements: members must share one ``DDPGConfig`` (pad
    ``action_dim`` to the population maximum for mixed-method
    populations; see the module docstring) and one chunk size. Members
    whose pending budgets diverge (e.g. different warmup positions)
    fall back to per-member fused flushes for that chunk.

    With ``fuse_rollouts=True``, members that are all
    ``FusedCompressionSearch`` over the same specs/sensitivity/context
    with the same methods (hence the same step list — the multi-
    hardware-target scenario, or multiple seeds) additionally share the
    rollout dispatch: one ``jit(vmap(rollout))`` over the stacked agent
    states, policy carries, and per-target ``HwParams``/latency-share
    arguments. Incompatible members silently keep their own (still
    fused) per-member rollout dispatch.
    """

    def __init__(self, members: Sequence[CompressionSearch],
                 fuse_rollouts: bool = False):
        if not members:
            raise ValueError("PopulationSearch needs at least one member")
        self.members = list(members)
        cfg0 = self.members[0].agent.cfg
        for m in self.members[1:]:
            if m.agent.cfg != cfg0:
                raise ValueError(
                    "population members must share a DDPGConfig (pad "
                    f"action_dim): {m.agent.cfg} != {cfg0}")
        if len({m._chunk_size() for m in self.members}) != 1:
            raise ValueError("population members must share a chunk size")
        self.fuse_rollouts = fuse_rollouts
        self._pop_rollout = None
        self._fusable = None

    def _rollouts_fusable(self) -> bool:
        """One vmapped rollout needs one traced step function: same spec
        list (identity — the oracle/legal/static tables bake into the
        trace), same sensitivity table, same context/window/methods (the
        step lists must coincide), same MXU alignment. Hardware rates
        and latency shares are arguments, so targets may differ."""
        if self._fusable is None:
            ms = self.members
            m0 = ms[0]
            self._fusable = all(isinstance(m, FusedCompressionSearch)
                                for m in ms) and \
                all(m.specs is m0.specs and m.sens is m0.sens
                    and m.ctx == m0.ctx
                    and m.cfg.window == m0.cfg.window
                    and m.cfg.methods == m0.cfg.methods
                    and m.hw.mxu_align == m0.hw.mxu_align
                    for m in ms[1:])
        return self._fusable

    def _run_fused_chunk(self, first_episode: int,
                         k: int) -> List[List[EpisodeRecord]]:
        """All members' rollouts as ONE vmapped dispatch, then the
        per-member validation/replay/record tail."""
        args = [m._rollout_args(first_episode, k) for m in self.members]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *args)
        if self._pop_rollout is None:
            self._pop_rollout = jax.jit(
                jax.vmap(self.members[0]._rollout_fn))
        outs = self._pop_rollout(*stacked)
        for m in self.members:     # ONE shared dispatch, logged on each
            m.dispatch_log.append("rollout")
        return [m._finish_batch(first_episode, k, tree_index(outs, i))
                for i, m in enumerate(self.members)]

    def run(self, episodes: Optional[int] = None,
            verbose: bool = False) -> List[SearchResult]:
        """Run all members for the same episode count; returns one
        ``SearchResult`` per member, aligned with ``self.members``."""
        n = episodes or min(m.cfg.episodes for m in self.members)
        histories = [[] for _ in self.members]
        bests = [None for _ in self.members]
        saved = [m._defer_updates for m in self.members]
        try:
            for m in self.members:
                m._defer_updates = True
            e = 0
            while e < n:
                k = min(self.members[0]._chunk_size(), n - e)
                if self.fuse_rollouts and self._rollouts_fusable():
                    chunks = self._run_fused_chunk(e, k)
                else:
                    chunks = [m._run_chunk(e, k) for m in self.members]
                for i, recs in enumerate(chunks):
                    for rec in recs:
                        histories[i].append(rec)
                        if bests[i] is None or rec.reward > bests[i].reward:
                            bests[i] = rec
                self._dispatch_updates()
                if verbose:
                    last = e + k - 1
                    row = " ".join(
                        f"{m.cfg.methods}:{histories[i][-1].reward:+.3f}"
                        for i, m in enumerate(self.members))
                    print(f"  ep {last:4d} rewards [{row}]")
                e += k
        finally:
            for m, flag in zip(self.members, saved):
                m._defer_updates = flag
        return [SearchResult(history=histories[i], best=bests[i],
                             ref_latency_s=m.ref_lat.total_s,
                             ref_accuracy=m.ref_acc)
                for i, m in enumerate(self.members)]

    def _dispatch_updates(self):
        """One vmapped chunk for the whole population when the members'
        budgets agree; per-member fused flushes otherwise."""
        ns = [m._pending_updates for m in self.members]
        ready = all(len(m.replay) >= m.agent.cfg.batch_size
                    for m in self.members)
        if len(set(ns)) == 1 and ns[0] > 0 and ready:
            n = ns[0]
            states = tree_stack(
                [m.agent.state_for_dispatch() for m in self.members])
            datas = tree_stack([m.replay.data for m in self.members])
            new_states, _losses = population_update_chunk(
                self.members[0].agent.cfg, states, datas, n)
            for i, m in enumerate(self.members):
                m.agent.adopt_state(tree_index(new_states, i))
                m._pending_updates = 0
                if isinstance(m, FusedCompressionSearch):
                    m.dispatch_log.append("update")   # shared dispatch
        else:
            for m in self.members:
                m._flush_updates()
