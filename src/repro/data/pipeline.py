"""Data pipeline: deterministic, shardable, restart-safe.

Production path: ``ShardedTokenDataset`` — memory-mapped token shards with
per-host slicing (host h of H reads rows h::H), deterministic shuffling by
step-seeded RNG, and an async host->device prefetcher. Synthetic generators
stand in for corpora that are not available offline (see DESIGN.md §6):

* ``bigram_lm`` — Zipfian bigram language: learnable structure for the
  Galen search testbed (accuracy degrades measurably under compression).
* ``blob_images`` — Gaussian-blob classes: CIFAR-10 stand-in for the
  paper's ResNet experiments.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Synthetic task 1: Zipfian bigram language modelling
# ---------------------------------------------------------------------------

def make_bigram_table(vocab: int, seed: int = 0,
                      branching: int = 4) -> np.ndarray:
    """Each token has `branching` likely successors — learnable structure."""
    rng = np.random.default_rng(seed)
    table = np.zeros((vocab, vocab), np.float64)
    for v in range(vocab):
        succ = rng.choice(vocab, size=branching, replace=False)
        probs = rng.dirichlet(np.ones(branching) * 0.5) * 0.9
        table[v, succ] = probs
        table[v] += 0.1 / vocab
        table[v] /= table[v].sum()
    return table


def sample_bigram(table: np.ndarray, batch: int, seq: int,
                  seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    vocab = table.shape[0]
    out = np.zeros((batch, seq), np.int32)
    out[:, 0] = rng.integers(0, vocab, batch)
    cdf = np.cumsum(table, axis=1)
    for t in range(1, seq):
        u = rng.random(batch)
        out[:, t] = np.argmax(cdf[out[:, t - 1]] > u[:, None], axis=1)
    return out


def bigram_lm(vocab: int, batch: int, seq: int, seed: int = 0) -> dict:
    table = make_bigram_table(vocab, seed)
    toks = sample_bigram(table, batch, seq, seed + 1)
    return {"tokens": jnp.asarray(toks)}


# ---------------------------------------------------------------------------
# Synthetic task 2: Gaussian-blob image classification (CIFAR stand-in)
# ---------------------------------------------------------------------------

def make_blob_protos(num_classes: int, img: int, channels: int = 3,
                     proto_seed: int = 1234) -> np.ndarray:
    """Fixed class prototypes (the 'dataset'); batches only vary noise."""
    rng = np.random.default_rng(proto_seed)
    protos = rng.normal(0, 1, (num_classes, img, img, channels))
    # low-pass so classes differ in coarse structure
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, 1) + np.roll(protos, 1, 2)) / 3
    return protos / protos.std()


def blob_images(num_classes: int, batch: int, img: int, seed: int = 0,
                channels: int = 3, noise: float = 1.3,
                proto_seed: int = 1234) -> dict:
    protos = make_blob_protos(num_classes, img, channels, proto_seed)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, batch)
    x = protos[labels] + rng.normal(0, noise, (batch, img, img, channels))
    return {"images": jnp.asarray(x, jnp.float32),
            "labels": jnp.asarray(labels, jnp.int32)}


# ---------------------------------------------------------------------------
# Production pipeline: sharded token shards + prefetch
# ---------------------------------------------------------------------------

@dataclass
class DataConfig:
    seq_len: int = 4096
    global_batch: int = 256
    shuffle_seed: int = 0
    prefetch: int = 2


class ShardedTokenDataset:
    """Deterministic per-host view over token shards.

    ``path`` may be a directory of ``*.npy`` uint16/uint32 token shards or
    ``synthetic://vocab`` to generate bigram data on the fly (offline mode).
    Restart safety: batches are a pure function of (seed, step) — resuming
    at step k reproduces the exact stream without replaying k batches.
    """

    def __init__(self, path: str, cfg: DataConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.host_batch = cfg.global_batch // num_hosts
        if path.startswith("synthetic://"):
            vocab = int(path.split("://")[1])
            self.table = make_bigram_table(vocab, cfg.shuffle_seed)
            self.tokens = None
        else:
            import glob
            import os
            files = sorted(glob.glob(os.path.join(path, "*.npy")))
            if not files:
                raise FileNotFoundError(f"no token shards under {path}")
            self.tokens = np.concatenate(
                [np.load(f, mmap_mode="r") for f in files])
            self.table = None

    def batch_at(self, step: int) -> dict:
        seed = (self.cfg.shuffle_seed * 1_000_003 + step) * self.num_hosts \
            + self.host_id
        if self.table is not None:
            toks = sample_bigram(self.table, self.host_batch,
                                 self.cfg.seq_len, seed)
        else:
            rng = np.random.default_rng(seed)
            n = len(self.tokens) - self.cfg.seq_len - 1
            starts = rng.integers(0, n, self.host_batch)
            toks = np.stack([self.tokens[s:s + self.cfg.seq_len]
                             for s in starts]).astype(np.int32)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread host->device prefetch (keeps the TPU fed)."""

    def __init__(self, it: Iterator[dict], depth: int = 2, sharding=None):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.sharding = sharding
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                arrs = {k: (jax.device_put(v, self.sharding)
                            if self.sharding is not None
                            else jnp.asarray(v))
                        for k, v in item.items()}
                self.q.put(arrs)

        self.thread = threading.Thread(target=work, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
