"""Per-kind residual blocks: attn(+MLP/MoE), Mamba-2 SSD, RG-LRU.

Each kind provides ``init_*``, ``apply_*`` (full sequence) and ``decode_*``
(single token + cache). Compression hooks: ``cspec`` — a dict pytree of quant
specs (``{"w_bits","a_bits"}``) and float 0/1 pruning masks; ``None`` means
uncompressed (all hooks compile away).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L


def _qs(cspec, key):
    return None if cspec is None else cspec.get(key)


def _mask(cspec, key):
    return None if cspec is None else cspec.get(key)


# ===========================================================================
# Attention sub-block
# ===========================================================================

def init_attention(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    H, KV, D, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": L.linear_init(ks[0], d, H * D, dtype, bias=cfg.qkv_bias),
        "wk": L.linear_init(ks[1], d, KV * D, dtype, bias=cfg.qkv_bias),
        "wv": L.linear_init(ks[2], d, KV * D, dtype, bias=cfg.qkv_bias),
        "wo": L.linear_init(ks[3], H * D, d, dtype),
    }
    return p


def _qkv(p, x, cfg: ArchConfig, cspec):
    B, S, _ = x.shape
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qs = _qs(cspec, "qkv")
    q = L.linear(p["wq"], x, qs).reshape(B, S, H, D)
    k = L.linear(p["wk"], x, qs).reshape(B, S, KV, D)
    v = L.linear(p["wv"], x, qs).reshape(B, S, KV, D)
    return q, k, v


def apply_attention(p, x, cfg: ArchConfig, cspec=None, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, cspec)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    causal = not cfg.is_encoder
    window = cfg.window if cfg.attention == "sliding" else 0
    o = L.attention(q, k, v, causal=causal, window=window,
                    head_mask=_mask(cspec, "head_mask"))
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return L.linear(p["wo"], o, _qs(cspec, "o"))


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                    cache_bits: int = 16):
    """cache_bits=8 stores K/V as int8 with per-(token, head) scales —
    halves the decode-dominating cache traffic (beyond-paper, §Perf)."""
    W = min(max_len, cfg.window) if cfg.attention == "sliding" else max_len
    KV, D = cfg.num_kv_heads, cfg.head_dim
    if cache_bits <= 8:
        return {
            "k": jnp.zeros((batch, W, KV, D), jnp.int8),
            "v": jnp.zeros((batch, W, KV, D), jnp.int8),
            "k_s": jnp.zeros((batch, W, KV), jnp.float32),
            "v_s": jnp.zeros((batch, W, KV), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, W, KV, D), dtype),
        "v": jnp.zeros((batch, W, KV, D), dtype),
    }


def _cache_write(cache, name, val, slot):
    """Write [B,1,KV,D] into the cache, quantizing if it is int8.

    NOTE (§Perf B4, REFUTED): a masked-select write (jnp.where on an iota
    mask) was hypothesized to keep length-sharded cache writes local;
    measured 3.6x MORE collective traffic than dynamic-update-slice —
    GSPMD handles the 1-slot DUS better than the broadcast select."""
    buf = cache[name]
    if buf.dtype == jnp.int8:
        scale = jnp.max(jnp.abs(val.astype(jnp.float32)), axis=-1) / 127.0
        scale = jnp.maximum(scale, 1e-8)                     # [B,1,KV]
        q = jnp.clip(jnp.round(val.astype(jnp.float32)
                               / scale[..., None]), -128, 127) \
            .astype(jnp.int8)
        buf = jax.lax.dynamic_update_slice(buf, q, (0, slot, 0, 0))
        sbuf = jax.lax.dynamic_update_slice(
            cache[name + "_s"], scale, (0, slot, 0))
        return {name: buf, name + "_s": sbuf}
    return {name: jax.lax.dynamic_update_slice(
        buf, val.astype(buf.dtype), (0, slot, 0, 0))}


def _cache_read(cache, name, dtype):
    buf = cache[name]
    if buf.dtype == jnp.int8:
        return (buf.astype(jnp.float32)
                * cache[name + "_s"][..., None]).astype(dtype)
    return buf


def decode_attention_block(p, x, cache, pos, cfg: ArchConfig, cspec=None):
    """x: [B,1,d]; pos: scalar current position. Returns (out, cache)."""
    B = x.shape[0]
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _qkv(p, x, cfg, cspec)
    pp = jnp.full((B, 1), pos)
    q = L.rope(q, pp, cfg.rope_theta)
    k = L.rope(k, pp, cfg.rope_theta)
    W = cache["k"].shape[1]
    ring = cfg.attention == "sliding"
    slot = jnp.mod(pos, W) if ring else pos
    new_cache = {}
    new_cache.update(_cache_write(cache, "k", k, slot))
    new_cache.update(_cache_write(cache, "v", v, slot))
    k_cache = _cache_read(new_cache, "k", x.dtype)
    v_cache = _cache_read(new_cache, "v", x.dtype)
    o = L.decode_attention(q, k_cache, v_cache, pos + 1,
                           window=cfg.window if ring else 0, ring=ring,
                           head_mask=_mask(cspec, "head_mask"))
    o = o.reshape(B, 1, H * D)
    out = L.linear(p["wo"], o, _qs(cspec, "o"))
    return out, new_cache


# ===========================================================================
# Dense MLP
# ===========================================================================

def init_mlp(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {"w_up": L.linear_init(ks[0], d, ff, dtype),
         "w_down": L.linear_init(ks[1], ff, d, dtype)}
    if gated:
        p["w_gate"] = L.linear_init(ks[2], d, ff, dtype)
    return p


def apply_mlp(p, x, cfg: ArchConfig, cspec=None):
    qs_up, qs_down = _qs(cspec, "up"), _qs(cspec, "down")
    ff_mask = _mask(cspec, "ff_mask")
    up = L.linear(p["w_up"], x, qs_up)
    gate = L.linear(p["w_gate"], x, qs_up) if "w_gate" in p else up
    h = L.mlp_act(cfg.mlp, gate, up)
    if ff_mask is not None:
        h = h * ff_mask.astype(h.dtype)
    h = shard(h, "batch", "seq", "ff")
    return L.linear(p["w_down"], h, qs_down)


# ===========================================================================
# MoE (top-k, capacity dispatch; optional Arctic dense residual)
# ===========================================================================

def init_moe(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 7)
    d, ff, E = cfg.d_model, cfg.d_ff, m.num_experts
    std = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * std
                   ).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * std
                 ).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * std
                   ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                   / math.sqrt(ff)).astype(dtype),
    }
    if m.dense_residual:
        p["dense_w_up"] = L.linear_init(ks[4], d, ff, dtype)["w"]
        p["dense_w_gate"] = L.linear_init(ks[5], d, ff, dtype)["w"]
        p["dense_w_down"] = L.linear_init(ks[6], ff, d, dtype)["w"]
    return p


def moe_dispatch(gates: jnp.ndarray, E: int, K: int, capacity: int):
    """Grouped (shard-local) dispatch. gates: [G, Tg, E] softmax probs ->
    (dispatch_idx [G,E,C], combine [G,Tg,K], slot [G,Tg,K], keep [G,Tg,K]).

    Positions are cumsum'd WITHIN each group; with the group axis sharded
    over ``data`` every gather stays shard-local (no global all-gather of
    the token activations — see DESIGN §4), and the expert einsum's
    resharding is exactly the EP all-to-all."""
    G, Tg, _ = gates.shape
    gate_vals, expert_idx = jax.lax.top_k(gates, K)          # [G, Tg, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx.reshape(G, Tg * K), E,
                            dtype=jnp.int32)                  # [G, Tg*K, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.sum(pos * onehot, -1)                           # [G, Tg*K]
    keep = pos < capacity
    e_flat = expert_idx.reshape(G, Tg * K)
    pos_c = jnp.where(keep, pos, capacity)                    # overflow slot
    tok = jnp.broadcast_to(jnp.arange(Tg * K) // K, (G, Tg * K))
    dispatch = jnp.full((G, E, capacity + 1), Tg, jnp.int32)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg * K))
    dispatch = dispatch.at[gi, e_flat, pos_c].set(tok)[:, :, :capacity]
    slot = jnp.where(keep, e_flat * capacity + pos, E * capacity)
    return (dispatch, gate_vals, slot.reshape(G, Tg, K),
            keep.reshape(G, Tg, K))


def _dispatch_groups(T: int, E: int) -> int:
    """Shard-local dispatch group count: the data-axis size, reduced when
    the per-group token count would be tiny (decode)."""
    from repro.distributed.sharding import current_axis_size
    G = current_axis_size("batch")
    while G > 1 and (T % G != 0 or T // G < 4 * E):
        G //= 2
    return max(1, G)


def apply_moe(p, x, cfg: ArchConfig, cspec=None):
    m = cfg.moe
    B, S, d = x.shape
    E, K, ff = m.num_experts, m.top_k, cfg.d_ff
    T = B * S
    G = _dispatch_groups(T, E)
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    xt = shard(xt, "batch", None, None)
    qs_up, qs_down = _qs(cspec, "up"), _qs(cspec, "down")
    ff_mask = _mask(cspec, "ff_mask")

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, -1)
    if Tg * E <= 4096:
        cap = Tg           # small token counts (decode/smoke): no dropping
    else:
        cap = int(math.ceil(K * Tg / E * m.capacity_factor))
        cap = max(4, -(-cap // 4) * 4)
    dispatch, gate_vals, slot, keep = moe_dispatch(gates, E, K, cap)

    xt_pad = jnp.concatenate([xt, jnp.zeros((G, 1, d), xt.dtype)], 1)
    idx = dispatch.reshape(G, E * cap)
    xe = jnp.take_along_axis(xt_pad, idx[..., None],
                             axis=1).reshape(G, E, cap, d)
    xe = shard(xe, "batch", "experts", None, None)

    dt = x.dtype
    w_up = L.getw(p, "w_up", dt)
    w_gate = L.getw(p, "w_gate", dt)
    w_down = L.getw(p, "w_down", dt)
    if qs_up is not None:
        xe = L.fq_act(xe, qs_up["a_bits"])
        w_up = L.fq_weight(w_up, qs_up["w_bits"])
        w_gate = L.fq_weight(w_gate, qs_up["w_bits"])
    up = jnp.einsum("gecd,edf->gecf", xe, w_up.astype(xe.dtype))
    gate = jnp.einsum("gecd,edf->gecf", xe, w_gate.astype(xe.dtype))
    h = L.mlp_act("swiglu" if cfg.mlp == "swiglu" else "geglu", gate, up)
    if ff_mask is not None:
        h = h * ff_mask[None, None, None].astype(h.dtype)
    h = shard(h, "batch", "experts", None, "ff")
    if qs_down is not None:
        h = L.fq_act(h, qs_down["a_bits"])
        w_down = L.fq_weight(w_down, qs_down["w_bits"])
    ye = jnp.einsum("gecf,efd->gecd", h, w_down.astype(h.dtype))
    if m.combine == "reduce_scatter":
        # §Perf A2: the down-proj contracts over the model-sharded ff dim;
        # constraining ye's d axis onto the model axis turns the partial-sum
        # combine into a REDUCE-SCATTER of [G,E,cap,d] (vs an all-reduce of
        # the full 2.5x-inflated capacity buffer). The token gather below is
        # d-local; only the final [G,Tg,d] output is all-gathered.
        ye = shard(ye, "batch", "experts", None, "ff")

    ye_flat = ye.reshape(G, E * cap, d)
    ye_flat = jnp.concatenate([ye_flat, jnp.zeros((G, 1, d), ye.dtype)], 1)
    per_tk = jnp.take_along_axis(
        ye_flat, slot.reshape(G, Tg * K)[..., None],
        axis=1).reshape(G, Tg, K, d)
    w = jnp.where(keep, gate_vals, 0.0).astype(per_tk.dtype)
    out = jnp.sum(per_tk * w[..., None], axis=2)
    if m.combine == "reduce_scatter":
        out = shard(out, "batch", None, "ff")      # still d-sharded
    out = out.reshape(B, S, d)

    if m.dense_residual:
        dspec = None
        if cspec is not None:
            dspec = {"up": cspec.get("dense_up"), "down": cspec.get("dense_down"),
                     "ff_mask": cspec.get("dense_ff_mask")}
        def as_linear(v):
            return v if isinstance(v, dict) else {"w": v}
        dense = apply_mlp({"w_up": as_linear(p["dense_w_up"]),
                           "w_gate": as_linear(p["dense_w_gate"]),
                           "w_down": as_linear(p["dense_w_down"])},
                          x, cfg, dspec)
        out = out + dense
    return out


# ===========================================================================
# Mamba-2 (SSD) block
# ===========================================================================

def ssm_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, nheads, conv_dim


def init_ssm(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * s.d_state + nheads  # z, x, B, C, dt
    p = {
        "in_proj": L.linear_init(ks[0], d, d_proj, dtype)["w"],
        "out_proj": L.linear_init(ks[1], d_inner, d, dtype)["w"],
        "conv_w": (jax.random.normal(ks[2], (s.conv_width, conv_dim),
                                     jnp.float32) / s.conv_width).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }
    return p


def _segsum(a):
    """a: [..., l] log-decays -> [..., l, l] lower-tri cumulative sums."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xh, dA, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan (Mamba-2, arXiv:2405.21060 listing 1).

    xh: [b,s,h,p] (dt-scaled inputs); dA: [b,s,h] log decay per step;
    Bm, Cm: [b,s,n] (ngroups=1). Returns y [b,s,h,p], final state [b,h,p,n].
    """
    b, s, h, pdim = xh.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    c = sp // chunk
    X = xh.reshape(b, c, chunk, h, pdim)
    A = dA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)      # [b,h,c,l]
    Bc = Bm.reshape(b, c, chunk, n)
    Cc = Cm.reshape(b, c, chunk, n)

    A_cum = jnp.cumsum(A, -1)                                  # [b,h,c,l]
    Lmat = jnp.exp(_segsum(A))                                 # [b,h,c,l,l]
    # intra-chunk (quadratic within chunk)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, Lmat, X)
    # chunk states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)            # [b,h,c,l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, X)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])                      # [b,h,c]
    s0 = (jnp.zeros((b, h, pdim, n), X.dtype)
          if init_state is None else init_state)

    def step(prev, inp):
        st, dec = inp                                          # [b,h,p,n],[b,h]
        out = prev                                             # state BEFORE chunk
        new = st + dec[..., None, None] * prev
        return new, out

    sts = states.transpose(1, 0, 2, 3, 4)                      # [c,b,h,p,n]
    dcs = chunk_decay.transpose(2, 0, 1)                       # [c,b,h]
    final, prev_states = jax.lax.scan(step, s0, (sts, dcs))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [b,c,h,p,n]
    state_decay = jnp.exp(A_cum)                               # [b,h,c,l]
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)
    Y = (Y_diag + Y_off).reshape(b, sp, h, pdim)[:, :s]
    return Y, final


def _ssm_inner(p, x, cfg, cspec, conv_state, ssm_state, *, decode=False):
    """Shared pre/post projection logic. x: [B,S,d]."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    qs_in, qs_out = _qs(cspec, "in"), _qs(cspec, "out")
    head_mask = _mask(cspec, "head_mask")

    w_in = L.getw(p, "in_proj", x.dtype)
    xin = x
    if qs_in is not None:
        xin = L.fq_act(xin, qs_in["a_bits"])
        w_in = L.fq_weight(w_in, qs_in["w_bits"])
    proj = jnp.einsum("bsd,dk->bsk", xin, w_in.astype(x.dtype))
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    y_conv, new_conv = L.causal_conv1d(jax.nn.silu(xbc), p["conv_w"],
                                       conv_state)
    xs, Bm, Cm = jnp.split(y_conv, [d_inner, d_inner + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])           # [B,S,h]
    a = -jnp.exp(p["A_log"])                                   # [h]
    dA = dt * a[None, None]
    xh = xs.reshape(*xs.shape[:2], nheads, s.head_dim)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]

    if decode:
        # single step: state' = exp(dA) state + B ⊗ x_dt ; y = C·state'
        dec = jnp.exp(dA[:, 0])                                # [B,h]
        upd = jnp.einsum("bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                         xh_dt[:, 0])
        new_state = dec[..., None, None] * ssm_state + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       new_state)[:, None]
    else:
        y, new_state = ssd_chunked(xh_dt, dA, Bm.astype(jnp.float32),
                                   Cm.astype(jnp.float32), s.chunk_size,
                                   ssm_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    if head_mask is not None:
        y = y * head_mask[None, None, :, None]
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2)
    y = L.apply_norm("rmsnorm", {"scale": p["norm_scale"]},
                     y * jax.nn.silu(z))
    w_out = L.getw(p, "out_proj", y.dtype)
    if qs_out is not None:
        y = L.fq_act(y, qs_out["a_bits"])
        w_out = L.fq_weight(w_out, qs_out["w_bits"])
    out = jnp.einsum("bsd,dk->bsk", y, w_out.astype(y.dtype))
    return out, new_conv, new_state


def apply_ssm(p, x, cfg: ArchConfig, cspec=None):
    out, _, _ = _ssm_inner(p, x, cfg, cspec, None, None)
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nheads, s.head_dim, s.d_state),
                           jnp.float32),
    }


def decode_ssm(p, x, cache, pos, cfg: ArchConfig, cspec=None):
    out, conv, state = _ssm_inner(p, x, cfg, cspec, cache["conv"],
                                  cache["state"], decode=True)
    return out, {"conv": conv, "state": state}


# ===========================================================================
# RG-LRU (Griffin / RecurrentGemma) recurrent block
# ===========================================================================

_LRU_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 5)
    p = {
        "w_x": L.linear_init(ks[0], d, w, dtype)["w"],
        "w_y": L.linear_init(ks[1], d, w, dtype)["w"],
        "w_out": L.linear_init(ks[2], w, d, dtype)["w"],
        "conv_w": (jax.random.normal(ks[3], (4, w), jnp.float32) / 4.0
                   ).astype(dtype),
        # per-channel (diagonal) gates — see DESIGN.md (Griffin uses
        # block-diagonal heads; diagonal is the width-1 special case)
        "w_a": jnp.zeros((w,), jnp.float32),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Λ init so a^c ≈ U(0.9, 0.999) at r=1 (Griffin App. A)
        "a_param": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _LRU_C)).astype(jnp.float32),
    }
    return p


def _rglru_gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(uf * p["w_i"] + p["b_i"])
    log_a = -_LRU_C * jax.nn.softplus(p["a_param"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _lru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h


def apply_rglru(p, x, cfg: ArchConfig, cspec=None):
    qs_in, qs_out = _qs(cspec, "in"), _qs(cspec, "out")
    wmask = _mask(cspec, "width_mask")
    w_x = L.getw(p, "w_x", x.dtype)
    w_y = L.getw(p, "w_y", x.dtype)
    w_out = L.getw(p, "w_out", x.dtype)
    xin = x
    if qs_in is not None:
        xin = L.fq_act(xin, qs_in["a_bits"])
        w_x = L.fq_weight(w_x, qs_in["w_bits"])
        w_y = L.fq_weight(w_y, qs_in["w_bits"])
    y = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xin, w_y.astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", xin, w_x.astype(x.dtype))
    u, _ = L.causal_conv1d(u, p["conv_w"])
    a, b = _rglru_gates(p, u)
    h = _lru_scan(a, b).astype(x.dtype)
    g = h * y
    if wmask is not None:
        g = g * wmask.astype(g.dtype)
    g = shard(g, "batch", "seq", "ff")
    if qs_out is not None:
        g = L.fq_act(g, qs_out["a_bits"])
        w_out = L.fq_weight(w_out, qs_out["w_bits"])
    return jnp.einsum("bsw,wd->bsd", g, w_out.astype(g.dtype))


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype):
    return {
        "state": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, 3, cfg.lru_width), dtype),
    }


def decode_rglru(p, x, cache, pos, cfg: ArchConfig, cspec=None):
    qs_in, qs_out = _qs(cspec, "in"), _qs(cspec, "out")
    wmask = _mask(cspec, "width_mask")
    w_x = L.getw(p, "w_x", x.dtype)
    w_y = L.getw(p, "w_y", x.dtype)
    w_out = L.getw(p, "w_out", x.dtype)
    xin = x
    if qs_in is not None:
        xin = L.fq_act(xin, qs_in["a_bits"])
        w_x = L.fq_weight(w_x, qs_in["w_bits"])
        w_y = L.fq_weight(w_y, qs_in["w_bits"])
    y = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xin, w_y.astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", xin, w_x.astype(x.dtype))
    u, conv = L.causal_conv1d(u, p["conv_w"], cache["conv"])
    a, b = _rglru_gates(p, u)
    h = a[:, 0] * cache["state"] + b[:, 0]
    g = (h[:, None].astype(x.dtype)) * y
    if wmask is not None:
        g = g * wmask.astype(g.dtype)
    if qs_out is not None:
        g = L.fq_act(g, qs_out["a_bits"])
        w_out = L.fq_weight(w_out, qs_out["w_bits"])
    out = jnp.einsum("bsw,wd->bsd", g, w_out.astype(g.dtype))
    return out, {"state": h, "conv": conv}
