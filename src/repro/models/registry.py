"""Architecture registry: ``--arch <id>`` -> (full config, smoke config)."""
from __future__ import annotations

from importlib import import_module

from repro.configs.base import ArchConfig

_MODULES = {
    "internvl2-2b": "repro.configs.internvl2_2b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "arctic-480b": "repro.configs.arctic_480b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = import_module(_MODULES[arch_id])
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
