"""Layer primitives shared by every assigned architecture.

Every matmul-bearing primitive takes an optional quant spec ``qs`` —
``{"w_bits": i32[], "a_bits": i32[]}`` — and optional structured-pruning
masks, so a Galen compression policy can flow through the whole model
(including ``lax.scan``-stacked layer stacks, where specs are stacked on a
leading layer axis). With ``qs=None``/``mask=None`` the hooks vanish
statically — the uncompressed model pays zero overhead.

Weight layout convention: ``[in, out]`` (biases ``[out]``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import fake_quant_act, fake_quant_weight
from repro.distributed.sharding import shard

# Short aliases used throughout the model code.
fq_act = fake_quant_act
fq_weight = fake_quant_weight


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Linear (+ fake quant + masks)
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
                scale: Optional[float] = None):
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)
    if bias:
        return {"w": w, "b": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def apply_quant(x: jnp.ndarray, w: jnp.ndarray, qs: Optional[dict]):
    """Apply activation/weight fake quantization per the spec."""
    if qs is not None:
        x = fake_quant_act(x, qs["a_bits"])
        w = fake_quant_weight(w, qs["w_bits"])
    return x, w


def materialize_weight(p, dtype):
    """Resolve a weight container (see core/deploy.py) to a dense array.
    Deployed int8/int4 storage dequantizes on the fly — HBM reads the
    integer container; the convert fuses into the consuming matmul."""
    if not isinstance(p, dict):
        return p
    if "w" in p:
        return p["w"]
    if "w_q" in p:
        return (p["w_q"].astype(dtype) * p["w_scale"].astype(dtype))
    if "w_p" in p:
        from repro.core.deploy import unpack_int4_weight
        wq = unpack_int4_weight(p["w_p"])
        return wq.astype(dtype) * p["w_scale"].astype(dtype)
    raise KeyError(f"no weight in container: {list(p)}")


def getw(container, name, dtype):
    """Fetch a possibly-deploy-quantized raw weight (MoE/SSM/RG-LRU/embed)."""
    v = container[name]
    if isinstance(v, dict):
        return materialize_weight(v, dtype)
    return v


def linear(p: dict, x: jnp.ndarray, qs: Optional[dict] = None,
           out_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    w = materialize_weight(p, x.dtype)
    x, w = apply_quant(x, w, qs)
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    if out_mask is not None:
        y = y * out_mask.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparametric_ln":
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, p: dict, x: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        xf = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return xf.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs        # [..., S, half]
    ang = ang[..., None, :]                                       # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — chunked (flash-style) jnp path, compiles on any backend with
# O(S·W) live memory; the Pallas kernel (repro/kernels/flash_attention.py) is
# the TPU fast path and is numerically checked against this implementation.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_scores_mask(qpos, kpos, causal: bool, window: int):
    """qpos [Q], kpos [K] -> bool mask [Q, K] (True = attend)."""
    qp = qpos[:, None]
    kp = kpos[None, :]
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    m &= kp >= 0
    return m


def attention(q, k, v, *, causal: bool, window: int = 0,
              q_chunk: int = 512, k_chunk: int = 1024,
              head_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """GQA attention. q: [B,S,H,D]; k,v: [B,S,KV,D]. window=0 -> unlimited.

    For S <= q_chunk falls back to one dense block; otherwise scans q-chunks
    (outer) and k-chunks (inner, online softmax) so the live score tensor is
    [Cq, Ck] per head group — the jnp equivalent of flash attention.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qq = q.reshape(B, S, KV, G, D)
    positions = jnp.arange(S)

    if S <= max(q_chunk, 512):  # small: single dense block
        s = jnp.einsum("bqkgd,blkd->bkgql", qq, k).astype(jnp.float32) * scale
        mask = _attn_scores_mask(positions, positions, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, -1)
        o = jnp.einsum("bkgql,blkd->bqkgd", p.astype(v.dtype), v)
        o = o.reshape(B, S, H, D)
        if head_mask is not None:
            o = o * head_mask[None, None, :, None].astype(o.dtype)
        return o

    n_q = -(-S // q_chunk)
    n_k = -(-S // k_chunk)
    S_pad_q, S_pad_k = n_q * q_chunk, n_k * k_chunk

    def pad_s(x, to):
        return jnp.pad(x, ((0, 0), (0, to - S)) + ((0, 0),) * (x.ndim - 2))

    qq_p = pad_s(qq, S_pad_q)
    k_p, v_p = pad_s(k, S_pad_k), pad_s(v, S_pad_k)
    qpos = jnp.pad(positions, (0, S_pad_q - S), constant_values=S)
    kpos = jnp.pad(positions, (0, S_pad_k - S), constant_values=-1)

    qc = qq_p.reshape(B, n_q, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    kc = k_p.reshape(B, n_k, k_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vc = v_p.reshape(B, n_k, k_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    qpc = qpos.reshape(n_q, q_chunk)
    kpc = kpos.reshape(n_k, k_chunk)

    def q_block(args):
        qi, qp = args  # qi: [B,KV,G,Cq,D], qp: [Cq]

        def k_step(carry, kargs):
            m_run, l_run, acc = carry
            ki, vi, kp = kargs  # [B,KV,Ck,D], [Ck]
            s = jnp.einsum("bkgqd,bkld->bkgql", qi, ki).astype(jnp.float32) * scale
            mask = _attn_scores_mask(qp, kp, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, -1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgql,bkld->bkgqd", p.astype(vi.dtype), vi).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), (kc, vc, kpc))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(q_block, (qc, qpc))                 # [n_q,B,KV,G,Cq,D]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S_pad_q, H, D)[:, :S]
    out = out.astype(v.dtype)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: int = 0, ring: bool = False,
                     head_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-token attention against a cache.

    q: [B,1,H,D]; caches: [B,W,KV,D]; cache_len: current length (scalar).
    ``ring=True`` means the cache is a ring buffer of size W (sliding
    window) — all valid slots are attended, positions already rotated.
    """
    B, _, H, D = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qq = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,blkd->bkgl", qq, k_cache).astype(jnp.float32)
    s = s / math.sqrt(D)
    slot = jnp.arange(W)
    valid = slot < cache_len if not ring else slot < jnp.minimum(cache_len, W)
    if window > 0 and not ring:
        valid &= slot > cache_len - 1 - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgl,blkd->bkgd", p.astype(v_cache.dtype), v_cache)
    o = o.reshape(B, 1, H, D)
    if head_mask is not None:
        o = o * head_mask[None, None, :, None].astype(o.dtype)
    return o


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def mlp_act(kind: str, gate: jnp.ndarray, up: Optional[jnp.ndarray]):
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate) * up
    if kind == "gelu":
        return jax.nn.gelu(gate)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (SSM / RG-LRU front conv)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """x: [B,S,C]; w: [K,C] depthwise. Returns y ([B,S,C]) and new state
    ([B,K-1,C]) holding the last K-1 inputs for streaming decode."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xs = jnp.concatenate([state, x], axis=1)            # [B, S+K-1, C]
    y = sum(xs[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xs[:, -(K - 1):] if K > 1 else state
    return y.astype(x.dtype), new_state
