"""Full language-model assembly for all assigned architectures.

``init(cfg, key)``            -> params pytree (scan-stacked when homogeneous)
``forward(cfg, params, ...)`` -> logits  (train / prefill)
``init_cache(cfg, batch, max_len)``
``decode_step(cfg, params, cache, tokens, pos)`` -> (logits, cache)

Compression: every entry point takes ``cspec`` (see ``repro/core/compress``)
— quant bit widths and pruning masks that flow through the stacked layers.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import blocks as B
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Per-kind block init/apply/decode/cache dispatch
# ---------------------------------------------------------------------------

def _init_block(kind: str, key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p = {"attn_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
             "attn": B.init_attention(ks[0], cfg, dtype),
             "mlp_norm": L.norm_init(cfg.norm, cfg.d_model, dtype)}
        if cfg.moe is not None:
            p["moe"] = B.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = B.init_mlp(ks[1], cfg, dtype)
        return p
    if kind == "ssm":
        return {"norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
                "ssm": B.init_ssm(ks[0], cfg, dtype)}
    if kind == "rglru":
        return {"mix_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
                "rglru": B.init_rglru(ks[0], cfg, dtype),
                "mlp_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
                "mlp": B.init_mlp(ks[1], cfg, dtype)}
    raise ValueError(kind)


def _apply_block(kind: str, p, x, cfg: ArchConfig, cspec, positions):
    cs = cspec or {}
    if kind == "attn":
        h = L.apply_norm(cfg.norm, p["attn_norm"], x)
        x = x + B.apply_attention(p["attn"], h, cfg, cs.get("attn"), positions)
        h = L.apply_norm(cfg.norm, p["mlp_norm"], x)
        if "moe" in p:
            x = x + B.apply_moe(p["moe"], h, cfg, cs.get("moe"))
        else:
            x = x + B.apply_mlp(p["mlp"], h, cfg, cs.get("mlp"))
        return x
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["norm"], x)
        return x + B.apply_ssm(p["ssm"], h, cfg, cs.get("ssm"))
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["mix_norm"], x)
        x = x + B.apply_rglru(p["rglru"], h, cfg, cs.get("rglru"))
        h = L.apply_norm(cfg.norm, p["mlp_norm"], x)
        return x + B.apply_mlp(p["mlp"], h, cfg, cs.get("mlp"))
    raise ValueError(kind)


def _init_block_cache(kind: str, cfg: ArchConfig, batch: int, max_len: int,
                      dtype, cache_bits: int = 16):
    if kind == "attn":
        return B.init_attn_cache(cfg, batch, max_len, dtype, cache_bits)
    if kind == "ssm":
        return B.init_ssm_cache(cfg, batch, dtype)
    if kind == "rglru":
        return B.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def _decode_block(kind: str, p, x, cache, pos, cfg: ArchConfig, cspec):
    cs = cspec or {}
    if kind == "attn":
        h = L.apply_norm(cfg.norm, p["attn_norm"], x)
        o, cache = B.decode_attention_block(p["attn"], h, cache, pos, cfg,
                                            cs.get("attn"))
        x = x + o
        h = L.apply_norm(cfg.norm, p["mlp_norm"], x)
        if "moe" in p:
            x = x + B.apply_moe(p["moe"], h, cfg, cs.get("moe"))
        else:
            x = x + B.apply_mlp(p["mlp"], h, cfg, cs.get("mlp"))
        return x, cache
    if kind == "ssm":
        h = L.apply_norm(cfg.norm, p["norm"], x)
        o, cache = B.decode_ssm(p["ssm"], h, cache, pos, cfg, cs.get("ssm"))
        return x + o, cache
    if kind == "rglru":
        h = L.apply_norm(cfg.norm, p["mix_norm"], x)
        o, cache = B.decode_rglru(p["rglru"], h, cache, pos, cfg,
                                  cs.get("rglru"))
        x = x + o
        h = L.apply_norm(cfg.norm, p["mlp_norm"], x)
        return x + B.apply_mlp(p["mlp"], h, cfg, cs.get("mlp")), cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init(cfg: ArchConfig, key) -> dict:
    dtype = L.dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: dict[str, Any] = {}
    if cfg.frontend != "audio_stub":
        params["embed"] = (jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model), jnp.float32)
            / (cfg.d_model ** 0.5)).astype(dtype)
    if cfg.scan_layers and cfg.homogeneous:
        kind = cfg.layer_kinds[0]
        per_layer = [_init_block(kind, keys[i], cfg, dtype)
                     for i in range(cfg.num_layers)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    else:
        params["blocks"] = [
            _init_block(cfg.layer_kinds[i], keys[i], cfg, dtype)
            for i in range(cfg.num_layers)]
    params["final_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = L.linear_init(keys[-2], cfg.d_model,
                                          cfg.vocab_size, dtype)["w"]
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params, tokens, embeds, cspec):
    ebits = None if cspec is None else cspec.get("embed_bits")
    if cfg.frontend == "audio_stub":
        return embeds  # [B, S, d] straight from the (stub) frontend
    table = L.getw(params, "embed", L.dtype_of(cfg.compute_dtype))
    if ebits is not None:
        table = L.fq_weight(table, ebits)
    x = jnp.take(table, tokens, axis=0).astype(L.dtype_of(cfg.compute_dtype))
    if cfg.frontend == "vision_stub" and embeds is not None:
        P = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x[:, P:]], axis=1)
    return x


def _unembed(cfg: ArchConfig, params, x, cspec):
    hbits = None if cspec is None else cspec.get("head_bits")
    if cfg.tie_embeddings:
        w = L.getw(params, "embed", x.dtype).T
    else:
        w = L.getw(params, "unembed", x.dtype)
    if hbits is not None:
        w = L.fq_weight(w, hbits)
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return shard(logits.astype(jnp.float32), "batch", "seq", "vocab")


def forward(cfg: ArchConfig, params, tokens=None, embeds=None, cspec=None,
            positions=None) -> jnp.ndarray:
    """Returns logits [B, S, vocab] (f32)."""
    x = _embed_inputs(cfg, params, tokens, embeds, cspec)
    x = shard(x, "batch", "seq", "embed")
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :]
    blocks_cs = None if cspec is None else cspec.get("blocks")

    if cfg.scan_layers and cfg.homogeneous:
        kind = cfg.layer_kinds[0]

        def body(h, layer):
            p_l, cs_l = layer
            h = _apply_block(kind, p_l, h, cfg, cs_l, positions)
            return h, None

        if cfg.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat == "full"
                      else jax.checkpoint_policies.dots_saveable)
            body = jax.checkpoint(body, policy=policy)
        x, _ = jax.lax.scan(body, x, (params["blocks"], blocks_cs))
    else:
        for i, p_l in enumerate(params["blocks"]):
            cs_l = None if blocks_cs is None else blocks_cs[i]
            fn = functools.partial(_apply_block, cfg.layer_kinds[i])
            if cfg.remat != "none":
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable
                    if cfg.remat == "full"
                    else jax.checkpoint_policies.dots_saveable,
                    static_argnums=(2,))   # cfg is static
            x = fn(p_l, x, cfg, cs_l, positions)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return _unembed(cfg, params, x, cspec)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None, cache_bits: int = 16) -> dict:
    dtype = dtype or L.dtype_of(cfg.compute_dtype)
    if cfg.scan_layers and cfg.homogeneous:
        kind = cfg.layer_kinds[0]
        per_layer = [_init_block_cache(kind, cfg, batch, max_len, dtype,
                                       cache_bits)
                     for _ in range(cfg.num_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    return [_init_block_cache(cfg.layer_kinds[i], cfg, batch, max_len, dtype,
                              cache_bits)
            for i in range(cfg.num_layers)]


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, cspec=None,
                embeds=None):
    """tokens: [B, 1] (or embeds for audio); pos: scalar int. Returns
    (logits [B, 1, V], new_cache)."""
    x = _embed_inputs(cfg, params, tokens, embeds, cspec)
    x = shard(x, "batch", "seq", "embed")
    blocks_cs = None if cspec is None else cspec.get("blocks")

    if cfg.scan_layers and cfg.homogeneous:
        kind = cfg.layer_kinds[0]

        def body(h, layer):
            p_l, c_l, cs_l = layer
            h, new_c = _decode_block(kind, p_l, h, c_l, pos, cfg, cs_l)
            return h, new_c

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache,
                                              blocks_cs))
    else:
        new_cache = []
        for i, (p_l, c_l) in enumerate(zip(params["blocks"], cache)):
            cs_l = None if blocks_cs is None else blocks_cs[i]
            x, c = _decode_block(cfg.layer_kinds[i], p_l, x, c_l, pos, cfg,
                                 cs_l)
            new_cache.append(c)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return _unembed(cfg, params, x, cspec), new_cache
