"""Small ResNet (the paper's own testbed family: ResNet18 / CIFAR-10) with
channel-prunable, quantizable convs. GroupNorm replaces BatchNorm to stay
purely functional (noted in DESIGN.md; does not change search dynamics).

``cspec`` here is a list (one entry per conv, in ``layer_specs`` order) of
``{"qs": {"w_bits","a_bits"} | None, "mask": [C_out] | None}``, plus a final
entry for the fc head (quant only).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantization import fake_quant_act, fake_quant_weight
from repro.core.spec import LayerSpec


@dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet-tiny"
    stages: Tuple[int, ...] = (2, 2, 2, 2)     # blocks per stage (ResNet18: 2,2,2,2)
    widths: Tuple[int, ...] = (16, 32, 64, 128)
    num_classes: int = 10
    in_channels: int = 3
    img_size: int = 16
    gn_groups: int = 8


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return {"w": jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * math.sqrt(2.0 / fan)}


def _gn(x, groups):
    B, H, W, C = x.shape
    g = math.gcd(groups, C)
    xr = x.reshape(B, H, W, g, C // g)
    mu = jnp.mean(xr, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xr, axis=(1, 2, 4), keepdims=True)
    return ((xr - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, H, W, C)


def _conv(p, x, stride, qs=None, mask=None):
    w = p["w"]
    if qs is not None:
        x = fake_quant_act(x, qs["a_bits"])
        w = fake_quant_weight(w, qs["w_bits"])
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if mask is not None:
        y = y * mask[None, None, None].astype(y.dtype)
    return y


def init(cfg: ResNetConfig, key):
    keys = iter(jax.random.split(key, 128))
    params = {"stem": _conv_init(next(keys), 3, 3, cfg.in_channels,
                                 cfg.widths[0])}
    stages = []
    cin = cfg.widths[0]
    for si, (n, w) in enumerate(zip(cfg.stages, cfg.widths)):
        blocks = []
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            blk = {"conv1": _conv_init(next(keys), 3, 3, cin, w),
                   "conv2": _conv_init(next(keys), 3, 3, w, w)}
            if stride != 1 or cin != w:
                blk["skip"] = _conv_init(next(keys), 1, 1, cin, w)
            blocks.append(blk)
            cin = w
        stages.append(blocks)
    params["stages"] = stages
    params["head"] = {
        "w": jax.random.normal(next(keys), (cin, cfg.num_classes),
                               jnp.float32) / math.sqrt(cin),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
    return params


def _iter_convs(cfg: ResNetConfig):
    """Yield (name, stage_idx, block_idx, which, stride, cin, cout,
    prunable)."""
    yield ("stem", -1, -1, "stem", 1, cfg.in_channels, cfg.widths[0], False)
    cin = cfg.widths[0]
    for si, (n, w) in enumerate(zip(cfg.stages, cfg.widths)):
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            # conv1 output channels are free to prune (internal dim)
            yield (f"s{si}.b{bi}.conv1", si, bi, "conv1", stride, cin, w, True)
            # conv2 feeds the residual sum — dependency, not prunable
            yield (f"s{si}.b{bi}.conv2", si, bi, "conv2", 1, w, w, False)
            if stride != 1 or cin != w:
                yield (f"s{si}.b{bi}.skip", si, bi, "skip", stride, cin, w,
                       False)
            cin = w


def layer_specs(cfg: ResNetConfig) -> list[LayerSpec]:
    specs = []
    hw = cfg.img_size
    idx = 0
    for (name, si, bi, which, stride, cin, cout, prunable) in _iter_convs(cfg):
        if which == "stem":
            pass
        elif which == "conv1" and bi == 0 and si > 0:
            hw = max(1, hw // 2)
        k = 1 if which == "skip" else 3
        px = hw * hw
        specs.append(LayerSpec(
            name=name, kind="conv", layer_idx=idx, in_dim=cin, out_dim=cout,
            prunable=prunable, prune_dim=cout if prunable else 0,
            prune_granularity=8,  # TPU sublane multiple for conv channels
            dep_group="" if prunable else "residual",
            quantizable=True, mix_supported=(which != "stem"),
            flops_per_token=2.0 * k * k * cin * cout * px,
            weight_elems=k * k * cin * cout,
            act_elems_per_token=cin * px,
            extra={"px": px}))
        idx += 1
    specs.append(LayerSpec(
        name="head", kind="head", layer_idx=idx,
        in_dim=cfg.widths[-1], out_dim=cfg.num_classes,
        prunable=False, quantizable=True, mix_supported=False,
        flops_per_token=2.0 * cfg.widths[-1] * cfg.num_classes,
        weight_elems=cfg.widths[-1] * cfg.num_classes,
        act_elems_per_token=cfg.widths[-1]))
    return specs


def forward(cfg: ResNetConfig, params, x, cspec: Optional[list] = None):
    """x: [B, H, W, C] -> logits [B, num_classes]."""
    def entry(i):
        if cspec is None:
            return None, None
        e = cspec[i]
        return e.get("qs"), e.get("mask")

    i = 0
    qs, mask = entry(i)
    h = _conv(params["stem"], x, 1, qs, mask)
    h = jax.nn.relu(_gn(h, cfg.gn_groups))
    i += 1
    cin = cfg.widths[0]
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            qs, mask = entry(i)
            y = _conv(blk["conv1"], h, stride, qs, mask)
            y = jax.nn.relu(_gn(y, cfg.gn_groups))
            i += 1
            qs, mask = entry(i)
            y = _conv(blk["conv2"], y, 1, qs, mask)
            y = _gn(y, cfg.gn_groups)
            i += 1
            if "skip" in blk:
                qs, mask = entry(i)
                h = _conv(blk["skip"], h, stride, qs, mask)
                i += 1
            h = jax.nn.relu(h + y)
    h = jnp.mean(h, axis=(1, 2))
    w, b = params["head"]["w"], params["head"]["b"]
    if cspec is not None and cspec[i] is not None and cspec[i].get("qs"):
        qs = cspec[i]["qs"]
        h = fake_quant_act(h, qs["a_bits"])
        w = fake_quant_weight(w, qs["w_bits"])
    return h @ w + b
