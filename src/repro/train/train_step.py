"""Train and serve step functions — the units the dry-run lowers.

``make_train_step(cfg, opt_cfg)`` -> step(params, opt_state, batch, ...)
computing next-token CE loss, grads, AdamW update (optionally QAT: a cspec
threads fake-quant through the forward — the paper's 30-epoch retraining).

``make_serve_step(cfg)`` -> step(params, cache, tokens, pos) for decode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim.grad_compression import (GradCompressionConfig,
                                          compress_grads)
from repro.optim.optimizer import (OptimizerConfig, adamw_update,
                                   get_schedule)


def _sharded_ce(logits, labels):
    """Cross-entropy that stays local when the vocab axis is TP-sharded:
    logsumexp + one-hot reduction are per-shard partial sums (tiny [B,S]
    all-reduces), instead of log_softmax + gather which forces GSPMD to
    replicate the FULL logits (8.6 GB/dev on mixtral — §Perf A1b)."""
    lse = jax.nn.logsumexp(logits, -1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * onehot, -1)
    return lse - label_logit


def lm_loss(cfg: ArchConfig, params, batch, cspec=None):
    """Next-token CE (decoder) or per-frame CE (encoder)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    logits = M.forward(cfg, params, tokens=tokens, embeds=embeds,
                       cspec=cspec)
    if cfg.is_encoder:
        # encoder: frame-classification CE against per-position labels
        return jnp.mean(_sharded_ce(logits, batch["labels"]))
    labels = tokens[:, 1:]
    nll = _sharded_ce(logits[:, :-1], labels)
    mask = jnp.ones_like(nll)
    if cfg.frontend == "vision_stub" and cfg.frontend_len > 0:
        pos = jnp.arange(nll.shape[1])[None]
        mask = (pos >= cfg.frontend_len - 1).astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    gc_cfg: Optional[GradCompressionConfig] = None,
                    cspec=None):
    """Returns step(params, opt_state, batch [, gc_residual]) ->
    (params, opt_state, metrics [, residual])."""
    sched = get_schedule(opt_cfg)
    gc_cfg = gc_cfg or GradCompressionConfig()

    def step(params, opt_state, batch, gc_residual=None):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, cspec))(params)
        if gc_cfg.kind != "none" and gc_residual is not None:
            grads, gc_residual = compress_grads(grads, gc_residual, gc_cfg)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, sched)
        metrics = {"loss": loss, **om}
        if gc_residual is not None:
            return params, opt_state, metrics, gc_residual
        return params, opt_state, metrics

    return step


def make_eval_step(cfg: ArchConfig, cspec=None):
    def step(params, batch):
        return lm_loss(cfg, params, batch, cspec)
    return step


def make_serve_step(cfg: ArchConfig, cspec=None):
    """One decode step: (params, cache, tokens [B,1], pos) ->
    (logits [B,1,V], cache)."""

    def step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos, cspec=cspec)

    return step


def make_prefill_step(cfg: ArchConfig, cspec=None):
    def step(params, tokens, embeds=None):
        return M.forward(cfg, params, tokens=tokens, embeds=embeds,
                         cspec=cspec)
    return step
