"""Training loop with checkpoint/restart, failure detection and straggler
mitigation hooks — the driver behind ``repro.launch.train``.

Also provides ``train_testbed_lm`` / ``train_testbed_resnet``: quick CPU
trainers for the Galen search testbeds (the stand-ins for the paper's
trained ResNet18, see DESIGN.md §6).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointing as ckpt
from repro.configs.base import ArchConfig
from repro.distributed.fault_tolerance import (FaultToleranceConfig,
                                               StepMonitor)
from repro.models import model as M
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 1000
    log_every: int = 50
    ckpt_every: int = 200
    ckpt_dir: Optional[str] = None
    ft: FaultToleranceConfig = field(default_factory=FaultToleranceConfig)


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: OptimizerConfig,
                 tcfg: TrainerConfig, params=None, seed: int = 0,
                 cspec=None):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.params = params if params is not None \
            else M.init(cfg, jax.random.PRNGKey(seed))
        self.opt_state = adamw_init(self.params, opt_cfg)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, cspec=cspec))
        self.step = 0
        self.monitor = StepMonitor(tcfg.ft)
        self.ckpt = (ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)

    def maybe_restore(self):
        if self.ckpt is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        restored, step, extra = ckpt.restore_latest(self.tcfg.ckpt_dir, tree)
        if restored is not None:
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = step
            print(f"[trainer] resumed from step {step}")

    def fit(self, data_iter, eval_fn: Optional[Callable] = None):
        history = []
        for batch in data_iter:
            if self.step >= self.tcfg.total_steps:
                break
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            dt = time.perf_counter() - t0
            self.monitor.record(self.step, dt)
            if self.step % self.tcfg.log_every == 0:
                loss = float(metrics["loss"])
                row = {"step": self.step, "loss": loss, "dt": dt}
                if eval_fn is not None:
                    row["eval"] = float(eval_fn(self.params))
                history.append(row)
            if self.ckpt and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt": self.opt_state},
                               extra={"data_step": self.step})
        if self.ckpt:
            self.ckpt.save(self.step, {"params": self.params,
                                       "opt": self.opt_state},
                           extra={"data_step": self.step})
            self.ckpt.wait()
        return history


# ---------------------------------------------------------------------------
# Testbed trainers (CPU, minutes) — produce the trained models the Galen
# search compresses in benchmarks/ and examples/.
# ---------------------------------------------------------------------------

def train_testbed_lm(cfg: ArchConfig, steps: int = 300, batch: int = 32,
                     seq: int = 64, seed: int = 0, lr: float = 3e-3):
    from repro.data.pipeline import bigram_lm, make_bigram_table, \
        sample_bigram
    params = M.init(cfg, jax.random.PRNGKey(seed))
    opt_cfg = OptimizerConfig(lr=lr, warmup_steps=20, total_steps=steps,
                              weight_decay=0.0)
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    table = make_bigram_table(cfg.vocab_size, seed)
    for s in range(steps):
        toks = sample_bigram(table, batch, seq, seed * 10_000 + s)
        params, opt_state, m = step_fn(params, opt_state,
                                       {"tokens": jnp.asarray(toks)})
    val = {"tokens": jnp.asarray(
        sample_bigram(table, 64, seq, seed * 10_000 + steps + 7))}
    logits = M.forward(cfg, params, tokens=val["tokens"])
    acc = float(jnp.mean((jnp.argmax(logits[:, :-1], -1)
                          == val["tokens"][:, 1:])))
    return params, val, acc


def train_testbed_resnet(rcfg, steps: int = 250, batch: int = 64,
                         seed: int = 0, lr: float = 1e-2):
    from repro.data.pipeline import blob_images
    from repro.models import resnet as R
    params = R.init(rcfg, jax.random.PRNGKey(seed))
    opt_cfg = OptimizerConfig(lr=lr, warmup_steps=10, total_steps=steps,
                              weight_decay=1e-4)
    opt_state = adamw_init(params, opt_cfg)

    def loss_fn(p, batch):
        logits = R.forward(rcfg, p, batch["images"])
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(
            lp, batch["labels"][:, None], -1))

    from repro.optim.optimizer import adamw_update, get_schedule
    sched = get_schedule(opt_cfg)

    @jax.jit
    def step_fn(p, st, b):
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        p, st, _ = adamw_update(p, g, st, opt_cfg, sched)
        return p, st, loss

    for s in range(steps):
        b = blob_images(rcfg.num_classes, batch, rcfg.img_size,
                        seed=seed * 10_000 + s)
        params, opt_state, loss = step_fn(params, opt_state, b)
    val = blob_images(rcfg.num_classes, 256, rcfg.img_size,
                      seed=seed * 10_000 + steps + 7)
    logits = R.forward(rcfg, params, val["images"])
    acc = float(jnp.mean((jnp.argmax(logits, -1) == val["labels"])))
    return params, val, acc
