"""ℓ1 structured pruning tests."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st

from repro.core.pruning import (head_scores, keep_mask, keep_mask_dynamic,
                                l1_scores, slice_indices)


@given(st.integers(1, 64), st.integers(0, 64))
@settings(max_examples=40, deadline=None)
def test_keep_mask_count(n, keep):
    scores = jnp.asarray(np.random.default_rng(n).random(n))
    m = keep_mask(scores, keep)
    assert int(jnp.sum(m)) == min(keep, n)
    assert set(np.unique(np.asarray(m))) <= {0.0, 1.0}


@given(st.integers(1, 32), st.integers(0, 40), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=60, deadline=None)
def test_keep_mask_dynamic_matches_static(n, keep, seed):
    """Traced variant selects exactly keep_mask's channels — including
    on tied scores (quantized score draw forces frequent ties)."""
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(np.round(rng.random(n) * 4) / 4)
    static = keep_mask(scores, keep)
    dynamic = keep_mask_dynamic(scores, jnp.int32(keep))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(dynamic))


def test_keep_mask_dynamic_traced():
    scores = jnp.asarray([0.1, 5.0, 0.2, 3.0, 0.05])
    out = jax.jit(keep_mask_dynamic)(scores, jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(keep_mask(scores, 2)))


def test_keep_mask_selects_largest():
    scores = jnp.asarray([0.1, 5.0, 0.2, 3.0, 0.05])
    m = np.asarray(keep_mask(scores, 2))
    assert list(np.nonzero(m)[0]) == [1, 3]


def test_keep_mask_ties():
    scores = jnp.ones((8,))
    m = keep_mask(scores, 3)
    assert int(jnp.sum(m)) == 3


def test_l1_scores_group():
    w1 = jnp.asarray([[1.0, -2.0], [0.0, 1.0]])   # col sums of |.|: 1, 3
    w2 = jnp.asarray([[2.0, 0.0], [1.0, 0.0]])    # 3, 0
    s = l1_scores([w1, w2])
    np.testing.assert_allclose(np.asarray(s), [4.0, 3.0])


def test_head_scores():
    d, H, hd = 8, 4, 2
    w = jnp.zeros((d, H * hd)).at[:, 2:4].set(1.0)  # head 1 hot
    s = np.asarray(head_scores(w, H))
    assert s.argmax() == 1
    assert s.shape == (H,)


def test_slice_indices_roundtrip():
    scores = jnp.asarray([3.0, 1.0, 2.0, 0.5])
    m = keep_mask(scores, 2)
    idx = slice_indices(m)
    assert list(idx) == [0, 2]
