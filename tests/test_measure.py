"""Measured-latency subsystem: calibration table, calibrated oracles
(scalar/batch/traced parity + fused dispatch bound), policy deployment
bucketing, and oracle_mode="measured" end to end on the tiny engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.compress import CompressibleLM
from repro.core.latency import (CONTAINERS, LatencyContext, V5E,
                                container_for_bits, get_jax_oracle,
                                policy_latency, policy_latency_batch)
from repro.core.measure import (CalibrationTable, MeasureConfig,
                                deploy_policy_params, fit_calibration,
                                fit_extra_factor, measure_policy,
                                policy_bits_by_name, uniform_policy)
from repro.core.policy import Policy
from repro.core.spec import LayerCMP
from repro.models import model as M

CFG = ArchConfig(name="meas", num_layers=2, d_model=64, num_heads=4,
                 num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=128,
                 scan_layers=True, compute_dtype="float32")
CTX = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)


@pytest.fixture(scope="module")
def cm():
    return CompressibleLM(CFG, M.init(CFG, jax.random.PRNGKey(0)))


def synth_table(cm):
    return CalibrationTable(
        ratios={s.kind: {"raw": 1.1, "int8": 1.7, "int4": 2.3}
                for s in cm.specs},
        extra={"attn": 1.4, "overhead": 1.4})


def mixed_policy(specs, seed=0):
    rng = np.random.RandomState(seed)
    pol = Policy.reference(specs)
    for s, c in zip(specs, pol.cmps):
        if not s.quantizable:
            continue
        pick = rng.randint(3)
        if pick == 1:
            c.mode, c.w_bits, c.a_bits = "INT8", 8, 8
        elif pick == 2 and s.mix_supported:
            c.mode, c.w_bits, c.a_bits = "MIX", 4, 4
    return pol


# --------------------------- calibration table ------------------------------

def test_table_roundtrip(tmp_path, cm):
    t = synth_table(cm)
    t.meta["note"] = "test"
    p = str(tmp_path / "calib.json")
    t.save(p)
    back = CalibrationTable.load(p)
    assert back.ratios == t.ratios
    assert back.extra_factor() == pytest.approx(1.4)
    assert back.overhead_factor() == pytest.approx(1.4)
    assert back.meta["note"] == "test"


def test_table_defaults_and_unit_factors(cm):
    t = CalibrationTable(ratios={"mlp_up": {"int8": 2.0}})
    assert t.factor("mlp_up", "int8") == 2.0
    assert t.factor("mlp_up", "raw") == 1.0       # missing container -> 1
    assert t.factor("nope", "int8") == 1.0        # missing kind -> 1
    assert t.extra_factor() == 1.0
    f = t.unit_factors(cm.specs)
    assert f.shape == (len(cm.specs), len(CONTAINERS))
    i8 = CONTAINERS.index("int8")
    for i, s in enumerate(cm.specs):
        want = 2.0 if s.kind == "mlp_up" else 1.0
        assert f[i, i8] == want


def test_fit_calibration_geomean():
    rows = [{"kind": "mlp_up", "container": "int8", "ratio": 2.0},
            {"kind": "mlp_up", "container": "int8", "ratio": 8.0},
            {"kind": "mlp_up", "container": "raw", "ratio": 1.5},
            {"kind": "head", "container": "int8", "ratio": float("inf")},
            {"kind": "head", "container": "int8", "ratio": -1.0},
            {"kind": "embed", "skipped": "whatever"}]
    t = fit_calibration(rows)
    assert t.factor("mlp_up", "int8") == pytest.approx(4.0)   # geomean
    assert t.factor("mlp_up", "raw") == pytest.approx(1.5)
    assert t.factor("head", "int8") == 1.0        # junk filtered out
    assert "embed" not in t.ratios


def test_fit_extra_factor_exact_on_ref(cm):
    """By construction the fitted residual makes the calibrated raw
    prediction reproduce the whole-model measurement exactly."""
    t = synth_table(cm)
    ref = Policy.reference(cm.specs)
    target = 2.5 * policy_latency(cm.specs, ref, V5E, CTX, calib=t).total_s
    fit_extra_factor(t, cm.specs, ref, target, V5E, CTX)
    got = policy_latency(cm.specs, ref, V5E, CTX, calib=t).total_s
    assert got == pytest.approx(target, rel=1e-9)


# --------------------------- calibrated oracles -----------------------------

def test_three_oracle_calibrated_parity(cm):
    """Scalar, numpy-batch and traced oracles agree under a calibration
    table, and all differ from the analytic numbers (factors applied)."""
    t = synth_table(cm)
    pols = [mixed_policy(cm.specs, s) for s in range(4)]
    scalar = np.array([policy_latency(cm.specs, p, V5E, CTX,
                                      calib=t).total_s for p in pols])
    batch = policy_latency_batch(cm.specs, pols, V5E, CTX, calib=t)
    np.testing.assert_allclose(batch.total_s, scalar, rtol=1e-12)
    jo = get_jax_oracle(cm.specs, V5E, CTX, calib=t)
    from repro.core.policy import stack_policies
    pb = stack_policies(cm.specs, pols)
    ut, et = jo.unit_times(pb.keep, pb.w_bits, pb.a_bits)
    traced = np.asarray(jo.totals(ut, et))
    np.testing.assert_allclose(traced, scalar, rtol=1e-4)
    analytic = np.array([policy_latency(cm.specs, p, V5E, CTX).total_s
                         for p in pols])
    assert np.all(scalar > analytic)    # factors > 1 everywhere


def test_oracle_cache_keyed_on_calib(cm):
    t1, t2 = synth_table(cm), synth_table(cm)
    a = get_jax_oracle(cm.specs, V5E, CTX, calib=t1)
    assert get_jax_oracle(cm.specs, V5E, CTX, calib=t1) is a
    assert get_jax_oracle(cm.specs, V5E, CTX, calib=t2) is not a
    assert get_jax_oracle(cm.specs, V5E, CTX) is not a


def test_calibrated_fused_dispatch_bound():
    """ISSUE 6 acceptance: oracle_mode="calibrated" keeps the fused
    rollout engine at the analytic engine's <=4-dispatch bound."""
    from benchmarks.search_setup import calibrated_fused_row
    row = calibrated_fused_row(batch_size=4, updates=4)
    assert row["dispatches_per_batch"] <= 4


def test_bad_oracle_mode_rejected(cm):
    from repro.core.search import CompressionSearch, SearchConfig
    with pytest.raises(ValueError, match="oracle_mode"):
        CompressionSearch(cm, {"tokens": jnp.zeros((1, 8), jnp.int32)},
                          SearchConfig(oracle_mode="wallclock"), CTX)


# --------------------------- deployment bucketing ---------------------------

def test_policy_bits_widest_wins(cm):
    """Scan-stacked arrays deploy at the widest width any layer asks
    for: one FP32 layer keeps the shared weight raw even when the other
    layer asks int8."""
    pol = uniform_policy(cm.specs, "int8")
    idx = [i for i, s in enumerate(cm.specs) if s.kind == "mlp_up"]
    pol.cmps[idx[0]] = LayerCMP(keep=cm.specs[idx[0]].prune_dim,
                                mode="FP32")
    bits = policy_bits_by_name(cm.specs, pol)
    assert bits["w_up"] == 32                  # widest (raw) wins
    assert bits["w_down"] == 8
    qp = deploy_policy_params(cm, pol)
    assert "w" in qp["blocks"]["mlp"]["w_up"]          # stayed raw
    assert "w_q" in qp["blocks"]["mlp"]["w_down"]      # int8 container


def test_deployed_policy_forward_runs(cm):
    """A mixed search policy deploys onto real integer containers and
    the deployed forward stays close to the reference model."""
    pol = uniform_policy(cm.specs, "int4")
    qp = deploy_policy_params(cm, pol)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 128)
    base = M.forward(CFG, cm.params, tokens=toks)
    out = M.forward(CFG, qp, tokens=toks)
    rel = float(jnp.linalg.norm(out - base) / jnp.linalg.norm(base))
    assert rel < 0.6


def test_measure_policy_memo(cm, monkeypatch):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 16),
                                          0, 128)}
    mcfg = MeasureConfig(warmup=1, repeats=1)
    pol = uniform_policy(cm.specs, "int8")
    t1 = measure_policy(cm, pol, batch, mcfg)
    assert t1 > 0
    # identical container signature -> memo hit, no re-deploy
    import repro.core.measure as measure_mod
    monkeypatch.setattr(
        measure_mod, "quantize_params_for_deploy",
        lambda *a, **k: pytest.fail("memo miss re-deployed params"))
    assert measure_policy(cm, pol, batch, mcfg) == t1


# --------------------------- measured search mode ---------------------------

@pytest.mark.slow
def test_measured_mode_times_top_k(cm):
    from repro.core.reward import RewardConfig
    from repro.core.ddpg import DDPGConfig
    from repro.core.search import CompressionSearch, SearchConfig
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 16),
                                          0, 128)}
    scfg = SearchConfig(
        methods="q", episodes=6, reward=RewardConfig(target_ratio=0.6),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=8, buffer_size=64),
        oracle_mode="measured", measure_top_k=2, seed=0)
    cm2 = CompressibleLM(CFG, cm.params)
    search = CompressionSearch(cm2, batch, scfg, CTX,
                               calib=synth_table(cm))
    res = search.run()
    assert res.measured is not None and len(res.measured) == 2
    for row in res.measured:
        assert row["measured_s"] > 0 and row["measured_ref_s"] > 0
        assert row["measured_ratio"] == pytest.approx(
            row["measured_s"] / row["measured_ref_s"])
        assert row["predicted_ratio"] > 0
    # sorted by reward, best first
    assert res.measured[0]["reward"] >= res.measured[1]["reward"]


def test_container_for_bits_buckets():
    assert container_for_bits(32) == "raw"
    assert container_for_bits(9) == "raw"
    assert container_for_bits(8) == "int8"
    assert container_for_bits(5) == "int8"
    assert container_for_bits(4) == "int4"
    assert container_for_bits(2) == "int4"
