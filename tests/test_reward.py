"""Reward function tests (paper Eq. 6)."""
import numpy as np
import pytest

try:
    from hypothesis import given
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, st

from repro.core.reward import (RewardConfig, absolute_reward,
                               compute_reward, compute_reward_batch,
                               hard_exponential_reward)


def test_max_at_target():
    """Reward is maximized exactly at T = c * T_ref."""
    base = absolute_reward(0.9, 30.0, 100.0, 0.3)
    assert base == pytest.approx(0.9)
    assert absolute_reward(0.9, 40.0, 100.0, 0.3) < base
    assert absolute_reward(0.9, 20.0, 100.0, 0.3) < base  # undershoot
    # penalized too (paper: "although the used reward also penalizes these")


@given(st.floats(0.01, 1.0), st.floats(1.0, 100.0))
def test_penalty_symmetric_in_ratio(c, t_ref):
    over = absolute_reward(0.5, c * t_ref * 1.2, t_ref, c)
    under = absolute_reward(0.5, c * t_ref * 0.8, t_ref, c)
    assert over == pytest.approx(under, rel=1e-6)


def test_beta_scales_penalty():
    r1 = absolute_reward(0.5, 60.0, 100.0, 0.3, beta=-1.0)
    r3 = absolute_reward(0.5, 60.0, 100.0, 0.3, beta=-3.0)
    assert (0.5 - r3) == pytest.approx(3 * (0.5 - r1))


def test_hard_exponential_only_penalizes_overshoot():
    assert hard_exponential_reward(0.9, 20.0, 100.0, 0.3) == 0.9
    assert hard_exponential_reward(0.9, 40.0, 100.0, 0.3) < 0.9


def test_dispatch():
    cfg = RewardConfig(target_ratio=0.5, beta=-2.0)
    assert compute_reward(cfg, 1.0, 50.0, 100.0) == pytest.approx(1.0)


def test_dispatch_absolute_uses_beta():
    """compute_reward must thread cfg.beta into the absolute reward."""
    cfg = RewardConfig(target_ratio=0.3, beta=-7.0)
    assert compute_reward(cfg, 0.9, 60.0, 100.0) == pytest.approx(
        absolute_reward(0.9, 60.0, 100.0, 0.3, beta=-7.0))


def test_dispatch_hard_exponential_uses_hard_beta():
    """Regression: kind="hard_exponential" used to ignore the config
    and always run with the -0.07 default exponent."""
    cfg = RewardConfig(target_ratio=0.3, kind="hard_exponential",
                       hard_beta=-0.5)
    got = compute_reward(cfg, 0.9, 60.0, 100.0)
    assert got == pytest.approx(
        hard_exponential_reward(0.9, 60.0, 100.0, 0.3, beta=-0.5))
    assert got != pytest.approx(
        hard_exponential_reward(0.9, 60.0, 100.0, 0.3, beta=-0.07))
    # undershoot stays unpenalized regardless of the exponent
    assert compute_reward(cfg, 0.9, 20.0, 100.0) == pytest.approx(0.9)


@pytest.mark.parametrize("kind", ["absolute", "hard_exponential"])
def test_compute_reward_batch_matches_scalar(kind):
    """The jnp batch form (used inside the fused rollout finish path)
    == the scalar host path, both reward kinds."""
    cfg = RewardConfig(target_ratio=0.4, beta=-2.0, kind=kind,
                       hard_beta=-0.11)
    accs = np.linspace(0.1, 0.9, 7)
    lats = np.linspace(20.0, 120.0, 7)
    want = [compute_reward(cfg, a, l, 100.0)
            for a, l in zip(accs, lats)]
    got = np.asarray(compute_reward_batch(
        cfg, accs.astype(np.float32), lats.astype(np.float32), 100.0))
    np.testing.assert_allclose(got, want, rtol=1e-5)
