"""Reward function tests (paper Eq. 6)."""
import pytest

try:
    from hypothesis import given
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, st

from repro.core.reward import (RewardConfig, absolute_reward, compute_reward,
                               hard_exponential_reward)


def test_max_at_target():
    """Reward is maximized exactly at T = c * T_ref."""
    base = absolute_reward(0.9, 30.0, 100.0, 0.3)
    assert base == pytest.approx(0.9)
    assert absolute_reward(0.9, 40.0, 100.0, 0.3) < base
    assert absolute_reward(0.9, 20.0, 100.0, 0.3) < base  # undershoot
    # penalized too (paper: "although the used reward also penalizes these")


@given(st.floats(0.01, 1.0), st.floats(1.0, 100.0))
def test_penalty_symmetric_in_ratio(c, t_ref):
    over = absolute_reward(0.5, c * t_ref * 1.2, t_ref, c)
    under = absolute_reward(0.5, c * t_ref * 0.8, t_ref, c)
    assert over == pytest.approx(under, rel=1e-6)


def test_beta_scales_penalty():
    r1 = absolute_reward(0.5, 60.0, 100.0, 0.3, beta=-1.0)
    r3 = absolute_reward(0.5, 60.0, 100.0, 0.3, beta=-3.0)
    assert (0.5 - r3) == pytest.approx(3 * (0.5 - r1))


def test_hard_exponential_only_penalizes_overshoot():
    assert hard_exponential_reward(0.9, 20.0, 100.0, 0.3) == 0.9
    assert hard_exponential_reward(0.9, 40.0, 100.0, 0.3) < 0.9


def test_dispatch():
    cfg = RewardConfig(target_ratio=0.5, beta=-2.0)
    assert compute_reward(cfg, 1.0, 50.0, 100.0) == pytest.approx(1.0)
