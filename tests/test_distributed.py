"""Distribution tests: sharding rules + a subprocess multi-device dry-run
(the main process must keep seeing exactly one CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_main_process_single_device():
    assert len(jax.devices()) == 1


def test_param_rules_cover_model_paths():
    from repro.distributed.sharding import logical_axes_for_path
    cases = {
        "blocks/attn/wq/w": ("fsdp", "heads"),
        "blocks/mlp/w_up/w": ("fsdp", "ff"),
        "blocks/moe/w_down": ("experts", "ff", "fsdp"),
        "embed": ("vocab", None),
        "blocks/ssm/in_proj": ("fsdp", "heads"),
        "blocks/rglru/w_x": ("fsdp", "ff"),
        "final_norm/scale": (None,),
    }
    for path, want in cases.items():
        nd = len(want)
        got = logical_axes_for_path(path, nd, stacked=False)
        assert got == want, (path, got, want)


def test_stacked_prepends_layers():
    from repro.distributed.sharding import logical_axes_for_path
    got = logical_axes_for_path("blocks/mlp/w_up/w", 3, stacked=True)
    assert got == ("layers", "fsdp", "ff")


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import repro.launch.dryrun as DR
    import repro.models.registry as REG
    import repro.configs.base as CB

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    orig = REG.get_config
    DR.get_config = lambda a, smoke=False: orig(a, smoke=True).replace(
        scan_layers=orig(a).scan_layers)
    CB.SHAPES_BY_NAME["train_4k"] = CB.ShapeConfig("train_4k", 64, 8, "train")
    CB.SHAPES_BY_NAME["decode_32k"] = CB.ShapeConfig(
        "decode_32k", 128, 8, "decode")
    DR.SHAPES_BY_NAME = CB.SHAPES_BY_NAME
    out = {}
    for arch, shp in [("qwen2-0.5b", "train_4k"), ("mixtral-8x22b", "train_4k"),
                      ("qwen2-0.5b", "decode_32k")]:
        row, _ = DR.lower_cell(arch, shp, mesh, probes=False)
        out[f"{arch}:{shp}"] = {k: row[k] for k in
                                ("flops", "collective_bytes", "dominant")}
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_subprocess_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for key, row in out.items():
        assert row["flops"] > 0, key
        assert row["collective_bytes"] > 0, key  # SPMD really sharded
