"""Data pipeline determinism + optimizer/schedule/grad-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (DataConfig, ShardedTokenDataset,
                                 make_bigram_table, sample_bigram)
from repro.optim.grad_compression import (GradCompressionConfig,
                                          compress_grads, init_residual)
from repro.optim.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   cosine_schedule, get_schedule,
                                   wsd_schedule)


# ------------------------------- data ---------------------------------------

def test_bigram_table_stochastic():
    t = make_bigram_table(64, seed=1)
    np.testing.assert_allclose(t.sum(1), 1.0, atol=1e-9)
    assert (t >= 0).all()


def test_batch_at_deterministic():
    ds = ShardedTokenDataset("synthetic://128",
                             DataConfig(seq_len=32, global_batch=8))
    a = ds.batch_at(17)["tokens"]
    b = ds.batch_at(17)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = ds.batch_at(18)["tokens"]
    assert not np.array_equal(a, c)


def test_host_sharding_distinct():
    cfg = DataConfig(seq_len=32, global_batch=8)
    d0 = ShardedTokenDataset("synthetic://128", cfg, host_id=0, num_hosts=2)
    d1 = ShardedTokenDataset("synthetic://128", cfg, host_id=1, num_hosts=2)
    assert d0.host_batch == 4
    assert not np.array_equal(d0.batch_at(0)["tokens"],
                              d1.batch_at(0)["tokens"])


def test_file_shards(tmp_path):
    toks = np.arange(10_000, dtype=np.int32) % 97
    np.save(tmp_path / "shard0.npy", toks)
    ds = ShardedTokenDataset(str(tmp_path), DataConfig(seq_len=16,
                                                       global_batch=4))
    b = ds.batch_at(0)["tokens"]
    assert b.shape == (4, 16)


# ------------------------------ optimizer -----------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0, schedule="constant",
                          grad_clip=0.0)
    st = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, st, _ = adamw_update(params, g, st, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip_metric():
    params = {"w": jnp.ones((4,))}
    cfg = OptimizerConfig(grad_clip=1.0, schedule="constant")
    st = adamw_init(params, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(params, g, st, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_wsd_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          decay_frac=0.2, schedule="wsd")
    f = wsd_schedule(cfg)
    assert float(f(0)) == pytest.approx(0.0)
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(50)) == pytest.approx(1.0)          # stable plateau
    assert float(f(100)) == pytest.approx(0.1, abs=0.02)  # decayed tail


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    f = cosine_schedule(cfg)
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(100)) == pytest.approx(0.0, abs=1e-6)


def test_moment_dtype_bf16():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    cfg = OptimizerConfig(moment_dtype="bfloat16")
    st = adamw_init(params, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16


# --------------------------- grad compression --------------------------------

def test_int8_compression_error_feedback():
    """Error feedback: residual carries what quantization dropped."""
    cfg = GradCompressionConfig(kind="int8")
    g = {"w": jnp.asarray([0.001, 1.0, -0.5])}
    r = init_residual(g)
    out, r2 = compress_grads(g, r, cfg)
    total = out["w"] + r2["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]),
                               atol=1e-6)


def test_topk_keeps_largest():
    cfg = GradCompressionConfig(kind="topk", topk_frac=0.25,
                                error_feedback=False)
    g = {"w": jnp.asarray([0.1, -5.0, 0.2, 0.3])}
    out, _ = compress_grads(g, init_residual(g), cfg)
    np.testing.assert_allclose(np.asarray(out["w"]), [0, -5.0, 0, 0])


def test_compressed_sgd_converges():
    """EF-compressed SGD still converges on a quadratic (Karimireddy'19)."""
    cfg = GradCompressionConfig(kind="topk", topk_frac=0.5)
    w = jnp.asarray([4.0, -2.0, 1.0, 3.0])
    r = {"w": jnp.zeros_like(w)}
    for _ in range(300):
        g = {"w": 2 * w}
        out, r = compress_grads(g, r, cfg)
        w = w - 0.05 * out["w"]
    assert float(jnp.sum(w ** 2)) < 1e-2
