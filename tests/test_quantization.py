"""Property tests for fake quantization (paper Eq. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st, hnp

from repro.core.quantization import fake_quant, fake_quant_weight, quantize

arrays = hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                                 min_side=2, max_side=32),
                    elements=st.floats(-100, 100, width=32))


@given(arrays, st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_quant_error_bound(x, bits):
    """|fq(x) - x| <= quantization step (per channel)."""
    x = jnp.asarray(x)
    span = jnp.max(x, 0) - jnp.min(x, 0)
    # mask near-constant channels at large magnitude: f32 cancellation in
    # s*x - z dominates there and the step bound is meaningless
    ok = span >= 1e-3 * (jnp.max(jnp.abs(x), 0) + 1e-3)
    out = fake_quant(x, bits, axis=(0,))
    step = jnp.maximum(span, 1e-8) / (2.0 ** bits - 1.0)
    err = jnp.abs(out - x)
    bound = step + 1e-3 * span + 1e-6
    assert bool(jnp.all(jnp.where(ok[None], err <= bound, True)))


@given(arrays)
@settings(max_examples=20, deadline=None)
def test_bits32_identity(x):
    x = jnp.asarray(x)
    out = fake_quant(x, 32, axis=(0,))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_monotone_in_x(bits):
    """Uniform quantization is monotone non-decreasing."""
    x = jnp.sort(jax.random.normal(jax.random.PRNGKey(0), (256,)))
    out = fake_quant(x[None, :].T, bits, axis=(0,))  # single channel
    d = jnp.diff(out[:, 0])
    assert bool(jnp.all(d >= -1e-6))


def test_quant_values_in_range():
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8)) * 10
    for bits in (2, 4, 8):
        q, s, z = quantize(x, bits, axis=(0,))
        n = 2.0 ** bits - 1
        assert bool(jnp.all(q >= -n)) and bool(jnp.all(q <= n))


def test_fewer_bits_more_error():
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 16))
    errs = [float(jnp.mean(jnp.abs(fake_quant(x, b, axis=(0,)) - x)))
            for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_straight_through_gradient():
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 4))
    g = jax.grad(lambda t: jnp.sum(fake_quant(t, 4, axis=(0,))))(x)
    # STE: gradient is (close to) ones except range-edge interactions
    assert float(jnp.mean(jnp.abs(g - 1.0))) < 0.15


def test_traced_bits():
    """bits may be a traced scalar (needed inside lax.scan)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 8))

    @jax.jit
    def f(b):
        return fake_quant_weight(x, b)

    out8 = f(jnp.int32(8))
    out32 = f(jnp.int32(32))
    np.testing.assert_allclose(np.asarray(out32), np.asarray(x), rtol=1e-6)
    assert float(jnp.max(jnp.abs(out8 - x))) > 0


# ------------------------------------------------- Pallas kernel routing

def test_kernel_route_matches_ref(monkeypatch):
    """GALEN_FQ_KERNEL=1 sends per-channel-last fake_quant through the
    fused Pallas kernel (interpreted off-TPU): same forward values,
    same STE gradient, same bits>=32 pass-through as the ref path."""
    from repro.core.quantization import fake_quant_act
    monkeypatch.delenv("GALEN_FQ_KERNEL", raising=False)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32, 16)) * 3.0
    ref_out = fake_quant_act(x, 4)
    ref32 = fake_quant_act(x, 32)
    monkeypatch.setenv("GALEN_FQ_KERNEL", "1")
    np.testing.assert_allclose(np.asarray(fake_quant_act(x, 4)),
                               np.asarray(ref_out), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fake_quant_act(x, 32)),
                               np.asarray(ref32), rtol=1e-6)
    w = jax.random.normal(jax.random.PRNGKey(6), (32, 8))
    g = jax.grad(lambda t: jnp.sum(fake_quant_weight(t, 4)))(w)
    assert float(jnp.mean(jnp.abs(g - 1.0))) < 0.15   # STE survives


def test_kernel_route_layout_guard(monkeypatch):
    """Non-channel-last reductions and 1-D inputs never route to the
    kernel, even when forced on."""
    from repro.core.quantization import _kernel_route
    monkeypatch.setenv("GALEN_FQ_KERNEL", "1")
    x2 = jnp.zeros((8, 4))
    assert _kernel_route(x2, (0,))
    assert not _kernel_route(x2, (1,))          # reduce over channels
    assert not _kernel_route(jnp.zeros(8), (0,))
    monkeypatch.setenv("GALEN_FQ_KERNEL", "0")
    assert not _kernel_route(x2, (0,))          # forced off
