"""Deployment-mode quantization + quantized KV cache + sharded CE tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.deploy import (deployed_bytes, quantize_params_for_deploy,
                               quantize_weight, unpack_int4_weight)
from repro.models import model as M

CFG = ArchConfig(name="dep", num_layers=2, d_model=64, num_heads=4,
                 num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=128,
                 compute_dtype="float32")


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


def test_int8_container_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    c = quantize_weight(w, 8)
    back = c["w_q"].astype(jnp.float32) * c["w_scale"]
    rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
    assert rel < 0.01


def test_int4_container_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    c = quantize_weight(w, 4)
    assert c["w_p"].shape == (16, 16)          # packed 2/byte along K
    back = unpack_int4_weight(c["w_p"]).astype(jnp.float32) * c["w_scale"]
    rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
    assert rel < 0.15


def test_int4_container_3d_moe():
    w = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16))  # [E, d, ff]
    c = quantize_weight(w, 4)
    back = unpack_int4_weight(c["w_p"]).astype(jnp.float32) * c["w_scale"]
    assert back.shape == w.shape
    rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
    assert rel < 0.15


@pytest.mark.parametrize("bits,max_rel", [(8, 0.01), (6, 0.04), (4, 0.15),
                                          (3, 0.30), (2, 0.80)])
def test_roundtrip_error_bounds(bits, max_rel):
    """quantize -> unpack/dequantize round-trip error is bounded by the
    asked grid, for the int8 container AND the packed-int4 one —
    including odd (2-/3-bit) requests, which must use their own
    ``2**(bits-1)-1`` grid instead of silently riding the int4 one."""
    w = jax.random.normal(jax.random.PRNGKey(10), (64, 32))
    c = quantize_weight(w, bits)
    q = unpack_int4_weight(c["w_p"]) if bits <= 4 else c["w_q"]
    qmax = 2 ** (bits - 1) - 1
    # symmetric clip: the -(qmax+1) code is never emitted
    assert int(jnp.min(q)) >= -qmax and int(jnp.max(q)) <= qmax
    back = q.astype(jnp.float32) * c["w_scale"]
    # no overshoot: dequantized range stays inside the symmetric +-absmax
    absmax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    assert bool(jnp.all(jnp.abs(back) <= absmax * (1 + 1e-6)))
    rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
    assert rel < max_rel
    # the grid actually honors the asked width: at most 2*qmax+1 codes
    assert len(np.unique(np.asarray(q))) <= 2 * qmax + 1


def test_odd_bits_use_their_own_grid():
    """A 2-bit ask must be coarser than a 4-bit ask of the same weight
    (the old code quantized both on the int4 grid)."""
    w = jax.random.normal(jax.random.PRNGKey(11), (64, 32))
    q2 = unpack_int4_weight(quantize_weight(w, 2)["w_p"])
    q4 = unpack_int4_weight(quantize_weight(w, 4)["w_p"])
    assert len(np.unique(np.asarray(q2))) <= 3
    assert len(np.unique(np.asarray(q4))) > 3


@pytest.mark.parametrize("bits", [0, 1, 9, 32, 4.0, "8", None])
def test_invalid_bits_rejected(bits):
    w = jnp.ones((4, 4))
    with pytest.raises((ValueError, TypeError)):
        quantize_weight(w, bits)


def test_odd_contraction_dim():
    """int4 packing needs an even K: quantize_weight says so clearly,
    and quantize_params_for_deploy leaves such weights raw (the same
    rule the raw_names branch applies)."""
    w = jax.random.normal(jax.random.PRNGKey(12), (5, 4))
    with pytest.raises(ValueError, match="even contraction"):
        quantize_weight(w, 4)
    assert "w_q" in quantize_weight(w, 8)      # int8 container is fine
    qp = quantize_params_for_deploy({"lin": {"w": w}}, 4)
    assert "w" in qp["lin"] and "w_p" not in qp["lin"]


def test_raw_named_odd_contraction():
    """Regression: the raw-names branch used the even-K guard for EVERY
    bit width, so an odd-contraction named weight (e.g. a 5-row MoE
    up-projection) silently stayed f32 even at int8 — the deployed
    model ran a different program than the policy claimed. int8 needs
    no packing and must deploy; int4 genuinely can't pack odd K and
    must stay raw."""
    w = jax.random.normal(jax.random.PRNGKey(13), (5, 4))
    qp8 = quantize_params_for_deploy({"moe": {"w_up": w}}, 8)
    assert "w_q" in qp8["moe"]["w_up"]
    qp4 = quantize_params_for_deploy({"moe": {"w_up": w}}, 4)
    assert qp4["moe"]["w_up"] is not None
    assert not isinstance(qp4["moe"]["w_up"], dict)   # stayed raw


def test_bits_for_per_name_deploy(params):
    """``bits_for`` deploys mixed containers per weight name: >8 or
    None keeps raw, 8 gets the int8 container, 4 the packed one."""
    widths = {"wq": 4, "wk": 4, "wv": 4, "wo": 8, "w_up": 8,
              "w_gate": 8, "w_down": 4, "embed": 8}
    qp = quantize_params_for_deploy(params, bits_for=widths.get)
    blocks = qp["blocks"]
    assert "w_p" in blocks["attn"]["wq"]
    assert "w_q" in blocks["attn"]["wo"]
    assert "w_q" in qp["embed"]
    assert "w_q" in blocks["mlp"]["w_up"]
    assert "w_p" in blocks["mlp"]["w_down"]
    # unnamed widths (unembed, norms) stay raw
    assert not isinstance(qp["unembed"], dict)
    toks = jax.random.randint(jax.random.PRNGKey(14), (2, 16), 0, 128)
    base = M.forward(CFG, params, tokens=toks)
    out = M.forward(CFG, qp, tokens=toks)
    rel = float(jnp.linalg.norm(out - base) / jnp.linalg.norm(base))
    assert rel < 0.6


@pytest.mark.parametrize("bits,max_rel,max_ratio", [(8, 0.1, 0.30),
                                                    (4, 0.6, 0.17)])
def test_deployed_forward(params, bits, max_rel, max_ratio):
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 128)
    base = M.forward(CFG, params, tokens=toks)
    qp = quantize_params_for_deploy(params, bits)
    out = M.forward(CFG, qp, tokens=toks)
    rel = float(jnp.linalg.norm(out - base) / jnp.linalg.norm(base))
    assert rel < max_rel
    assert deployed_bytes(qp) / deployed_bytes(params) < max_ratio


def test_quantized_cache_decode(params):
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0, 128)
    full = M.forward(CFG, params, tokens=toks)
    cache = M.init_cache(CFG, 2, 12, dtype=jnp.float32, cache_bits=8)
    assert cache["k"].dtype == jnp.int8
    outs = []
    for t in range(12):
        lg, cache = M.decode_step(CFG, params, cache, toks[:, t:t + 1], t)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.linalg.norm(dec - full) / jnp.linalg.norm(full))
    assert rel < 0.05   # int8 cache ~1% noise


def test_sharded_ce_matches_log_softmax():
    from repro.train.train_step import _sharded_ce
    logits = jax.random.normal(jax.random.PRNGKey(6), (4, 8, 32))
    labels = jax.random.randint(jax.random.PRNGKey(7), (4, 8), 0, 32)
    want = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                labels[..., None], -1)[..., 0]
    got = _sharded_ce(logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_moe_deploy(params):
    from repro.configs.base import MoEConfig
    cfg = CFG.replace(moe=MoEConfig(num_experts=4, top_k=2))
    p = M.init(cfg, jax.random.PRNGKey(8))
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, 128)
    base = M.forward(cfg, p, tokens=toks)
    qp = quantize_params_for_deploy(p, 8)
    out = M.forward(cfg, qp, tokens=toks)
    rel = float(jnp.linalg.norm(out - base) / jnp.linalg.norm(base))
    assert rel < 0.1
