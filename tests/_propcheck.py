"""Seeded-random fallback for ``hypothesis`` when it is not installed.

Provides API-compatible shims for the subset this suite uses:

  * ``given(*strategies)`` — draws ``max_examples`` samples per test
    from a deterministic per-test RNG (seeded by the test's qualified
    name, so failures reproduce) and calls the test once per sample.
  * ``settings(max_examples=..., deadline=...)`` — records
    ``max_examples``; other knobs are accepted and ignored.
  * ``strategies`` (``st``) — ``floats``, ``integers``, ``booleans``,
    ``sampled_from``; each supports ``.map(f)``.
  * ``hnp`` — ``arrays`` / ``array_shapes`` from
    ``hypothesis.extra.numpy``.

No shrinking, no database — just uniform sampling with occasional
endpoint probes (real hypothesis is used automatically when present;
see the try/except imports in the test modules).
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_EXAMPLES = 25
_ENDPOINT_PROB = 0.1


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f):
        return Strategy(lambda rng: f(self._draw(rng)))


class strategies:
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, width=64, **_):
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            if rng.random() < _ENDPOINT_PROB:
                x = lo if rng.random() < 0.5 else hi
            else:
                x = lo + (hi - lo) * rng.random()
            return float(np.float32(x)) if width == 32 else x

        return Strategy(draw)

    @staticmethod
    def integers(min_value=0, max_value=100, **_):
        lo, hi = int(min_value), int(max_value)

        def draw(rng):
            if rng.random() < _ENDPOINT_PROB:
                return lo if rng.random() < 0.5 else hi
            return int(rng.integers(lo, hi + 1))

        return Strategy(draw)

    @staticmethod
    def booleans():
        return Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(items):
        seq = list(items)
        return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


st = strategies


class hnp:
    """Shim for ``hypothesis.extra.numpy``."""

    @staticmethod
    def array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=10):
        def draw(rng):
            nd = int(rng.integers(min_dims, max_dims + 1))
            return tuple(int(rng.integers(min_side, max_side + 1))
                         for _ in range(nd))

        return Strategy(draw)

    @staticmethod
    def arrays(dtype, shape, elements=None):
        def draw(rng):
            shp = shape.example(rng) if isinstance(shape, Strategy) \
                else tuple(shape)
            n = int(np.prod(shp)) if shp else 1
            if elements is not None:
                flat = [elements.example(rng) for _ in range(n)]
                return np.asarray(flat, dtype).reshape(shp)
            return rng.random(shp).astype(dtype)

        return Strategy(draw)


def settings(max_examples=None, deadline=None, **_):
    """Records max_examples on the decorated function (either side of
    ``given`` — attributes are looked up at call time)."""

    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (getattr(wrapper, "_propcheck_max_examples", None)
                 or getattr(fn, "_propcheck_max_examples", None)
                 or DEFAULT_EXAMPLES)
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                vals = [s.example(rng) for s in strats]
                fn(*args, *vals, **kwargs)

        # pytest must not mistake the strategy-filled parameters for
        # fixtures: expose a signature without the rightmost len(strats)
        # params (hypothesis fills positional strategies from the right)
        params = list(inspect.signature(fn).parameters.values())
        keep = params[:len(params) - len(strats)]
        wrapper.__signature__ = inspect.Signature(keep)
        del wrapper.__dict__["__wrapped__"]
        wrapper.hypothesis_shim = True
        return wrapper

    return deco
