"""Elastic re-mesh: a checkpoint written under one host layout restores
under another (the fault-tolerance path for shrinking the data axis)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointing as C
from repro.data.pipeline import DataConfig, ShardedTokenDataset
from repro.distributed.fault_tolerance import elastic_data_axis


def test_checkpoint_restores_across_layouts(tmp_path):
    """Leaves are stored unsharded; restore works regardless of the mesh
    the job restarts with (shardings argument optional)."""
    tree = {"params": {"w": jnp.arange(64.0).reshape(8, 8)},
            "opt": {"m": jnp.ones((8, 8))}}
    C.save(str(tmp_path), 42, tree, extra={"data_step": 42})
    # simulate a restart with a different (here: host-local) placement
    restored, step, extra = C.restore_latest(str(tmp_path), tree)
    assert step == 42 and extra["data_step"] == 42
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_data_pipeline_rescales_with_hosts():
    """After elastic shrink 4 -> 2 hosts the global batch is preserved and
    batches stay deterministic functions of (seed, step)."""
    cfg = DataConfig(seq_len=16, global_batch=8)
    four = [ShardedTokenDataset("synthetic://64", cfg, host_id=h,
                                num_hosts=4) for h in range(4)]
    two = [ShardedTokenDataset("synthetic://64", cfg, host_id=h,
                               num_hosts=2) for h in range(2)]
    g4 = np.concatenate([d.batch_at(5)["tokens"] for d in four])
    g2 = np.concatenate([d.batch_at(5)["tokens"] for d in two])
    assert g4.shape == g2.shape == (8, 16)
    # determinism per layout
    g2b = np.concatenate([d.batch_at(5)["tokens"] for d in two])
    np.testing.assert_array_equal(g2, g2b)


def test_elastic_axis_then_trainer_restore(tmp_path):
    """End-to-end: train 4 steps, 'lose a host', restore with the shrunken
    data axis and continue — losses stay finite."""
    from repro.configs.base import ArchConfig
    from repro.optim.optimizer import OptimizerConfig
    from repro.train.trainer import Trainer, TrainerConfig

    assert elastic_data_axis(3, 4, 4) == 2   # 12 chips, model=4 -> data=2

    cfg = ArchConfig(name="el", num_layers=1, d_model=32, num_heads=2,
                     num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    tcfg = TrainerConfig(total_steps=4, ckpt_every=2, log_every=2,
                         ckpt_dir=str(tmp_path))
    ds = ShardedTokenDataset("synthetic://64",
                             DataConfig(seq_len=16, global_batch=4))
    tr = Trainer(cfg, ocfg, tcfg, seed=0)
    tr.fit(ds.batch_at(s) for s in range(10))

    tcfg2 = TrainerConfig(total_steps=8, ckpt_every=4, log_every=2,
                          ckpt_dir=str(tmp_path))
    ds2 = ShardedTokenDataset("synthetic://64",
                              DataConfig(seq_len=16, global_batch=4),
                              host_id=0, num_hosts=2)  # shrunken layout
    tr2 = Trainer(cfg, ocfg, tcfg2, seed=7)
    tr2.maybe_restore()
    assert tr2.step == 4
    hist = tr2.fit(ds2.batch_at(s) for s in range(tr2.step, 12))
    assert all(np.isfinite(h["loss"]) for h in hist)
