"""Search-loop mechanics (short runs; learning quality is benchmarked, not
unit-tested)."""
import numpy as np
import pytest

from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import CompressionSearch, SearchConfig
from repro.core.state import state_dim


def _search(tiny_lm, methods, episodes=4):
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=episodes,
        reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=16, buffer_size=256))
    return CompressionSearch(cm, batch, scfg, ctx)


@pytest.mark.parametrize("methods", ["p", "q", "pq"])
def test_search_runs_all_agents(tiny_lm, methods):
    search = _search(tiny_lm, methods)
    res = search.run()
    assert len(res.history) == 4
    for rec in res.history:
        assert np.isfinite(rec.reward)
        assert 0.0 <= rec.accuracy <= 1.0
        assert rec.latency_s > 0
        assert len(rec.policy.cmps) == len(search.specs)


def test_policy_cmps_legal(tiny_lm):
    search = _search(tiny_lm, "pq")
    rec = search.run_episode(0)
    for s, c in zip(search.specs, rec.policy.cmps):
        if s.prunable and s.prune_dim:
            assert c.keep % s.prune_granularity == 0 or c.keep == s.prune_dim
        if c.mode == "MIX":
            assert s.mix_supported
        if not s.quantizable:
            assert c.mode == "FP32"


def test_reference_ratio_one(tiny_lm):
    search = _search(tiny_lm, "pq")
    from repro.core.latency import policy_latency
    lat = policy_latency(search.specs, search.ref_policy, search.hw,
                         search.ctx)
    assert lat.total_s == pytest.approx(search.ref_lat.total_s)


def test_transitions_pushed(tiny_lm):
    search = _search(tiny_lm, "pq")
    search.run_episode(0)
    assert len(search.replay) == len(search.steps)


def test_state_dim_matches(tiny_lm):
    search = _search(tiny_lm, "pq")
    assert search.agent.cfg.state_dim == state_dim(3)


def test_pruning_agent_skips_dependent_layers(tiny_lm):
    search = _search(tiny_lm, "p")
    names = [search.specs[i].name for i in search.steps]
    assert all("down" not in n and "attn_out" not in n for n in names)
    assert not any(n in ("embed", "head") for n in names)
