"""Fault-tolerance substrate: stragglers, heartbeats, elastic re-mesh."""
import pytest

from repro.distributed.fault_tolerance import (FaultToleranceConfig,
                                               HealthLedger, StepMonitor,
                                               StepTimeout, elastic_data_axis)


def test_straggler_detection():
    mon = StepMonitor(FaultToleranceConfig(straggler_factor=2.0))
    for i in range(20):
        mon.record(i, 0.1)
    mon.record(20, 0.5)                 # 5x median -> straggler
    assert 20 in mon.stragglers
    mon.record(21, 0.11)
    assert 21 not in mon.stragglers


def test_hard_timeout():
    mon = StepMonitor(FaultToleranceConfig(hard_timeout_s=1.0))
    for i in range(10):
        mon.record(i, 0.1)
    with pytest.raises(StepTimeout):
        mon.record(10, 2.0)


def test_health_ledger():
    cfg = FaultToleranceConfig(heartbeat_timeout_s=10.0)
    led = HealthLedger(4, cfg)
    now = 1000.0
    for h in range(4):
        led.heartbeat(h, now)
    led.heartbeat(0, now + 20)
    led.heartbeat(1, now + 20)
    led.heartbeat(2, now + 20)
    failed = led.failed_hosts(now + 21)
    assert failed == [3]
    led.exclude(failed)
    assert led.healthy == [0, 1, 2]
    assert led.failed_hosts(now + 21) == []


def test_elastic_data_axis():
    # 64 hosts x 4 chips, model=16 -> data=16; lose 3 hosts -> data=8
    assert elastic_data_axis(64, 4, 16) == 16
    assert elastic_data_axis(61, 4, 16) == 8
    assert elastic_data_axis(1, 4, 16) == 1
