"""Latency-oracle properties + HLO collective parsing."""
import pytest

from repro.configs.base import ArchConfig
from repro.core.compress import lm_layer_specs
from repro.core.constraints import legalize
from repro.core.latency import (V5E, LatencyContext, hlo_collective_bytes,
                                policy_latency)
from repro.core.policy import Policy
from repro.core.spec import LayerCMP

CFG = ArchConfig(name="o", num_layers=4, d_model=256, num_heads=8,
                 num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=512)
SPECS = lm_layer_specs(CFG)
CTX = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)


def mk(mode="FP32", wb=32, ab=32, keep=1.0):
    pol = Policy([LayerCMP(keep=max(1, int(s.prune_dim * keep))
                           if s.prune_dim else 0,
                           mode=mode, w_bits=wb, a_bits=ab) for s in SPECS])
    for s, c in zip(SPECS, pol.cmps):
        legalize(s, c)
    return pol


def total(pol, ctx=CTX):
    return policy_latency(SPECS, pol, V5E, ctx).total_s


def test_quant_monotone():
    assert total(mk("INT8", 8, 8)) < total(mk("FP32"))
    assert total(mk("MIX", 4, 4)) < total(mk("INT8", 8, 8))


def test_mix6_no_better_than_int8():
    """The TPU truth the paper found on ARM: 5-6 bit MIX buys nothing."""
    assert total(mk("MIX", 6, 6)) >= total(mk("INT8", 8, 8)) * 0.999


def test_prune_monotone():
    lats = [total(mk(keep=k)) for k in (1.0, 0.5, 0.25)]
    assert lats[0] > lats[1] > lats[2]


def test_padding_staircase():
    """Kept counts within one 128-granule cost the same (MXU padding)."""
    s = [sp for sp in SPECS if sp.kind == "mlp_up"][0]
    pol_a, pol_b = mk(), mk()
    i = SPECS.index(s)
    pol_a.cmps[i] = LayerCMP(keep=257)     # pads to 384
    pol_b.cmps[i] = LayerCMP(keep=384)
    la = policy_latency(SPECS, pol_a, V5E, CTX)
    lb = policy_latency(SPECS, pol_b, V5E, CTX)
    assert la.units[i].compute_s == pytest.approx(lb.units[i].compute_s)


def test_chips_scale():
    c2 = LatencyContext(tokens=1, seq_ctx=512, mode="decode", chips=4)
    assert total(mk(), c2) < total(mk(), CTX)


def test_decode_cache_term_present():
    lat = policy_latency(SPECS, mk(), V5E, CTX)
    names = [u.name for u in lat.units]
    assert any(n.endswith(".attn") for n in names)


HLO = """
ENTRY %main {
  %ag = bf16[4,256]{1,0} all-gather(bf16[1,256]{1,0} %p0), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%add
  %ar2 = f32[64,2]{1,0} all-reduce-start(f32[64,2]{1,0} %y), to_apply=%add
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(f32[64]{0} %z, f32[64]{0} %w)
  %cp = u8[16]{0} collective-permute(u8[16]{0} %q)
  %a2a = s8[8,8]{1,0} all-to-all(s8[8,8]{1,0} %r)
}
"""


def test_hlo_collective_parse():
    out = hlo_collective_bytes(HLO)
    assert out["all-gather"] == 4 * 256 * 2
    assert out["all-reduce"] == 128 * 4 + 64 * 2 * 4
    assert out["reduce-scatter"] == 32 * 4 * 2
    assert out["collective-permute"] == 16
    assert out["all-to-all"] == 64
    assert out["_counts"]["all-reduce"] == 2


DOT_HLO_INT8 = """
ENTRY %main {
  %d = s32[64,64]{1,0} dot(s8[64,128]{1,0} %x, s8[128,64]{1,0} %w),
    lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %u = f32[64,64]{1,0} convert(s32[64,64]{1,0} %d)
}
"""

DOT_HLO_BF16 = """
ENTRY %main {
  %d = f32[64,64]{1,0} dot(bf16[64,128]{1,0} %x, bf16[128,64]{1,0} %w),
    lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_hlo_compute_dtype():
    from repro.core.latency import hlo_compute_dtype
    assert hlo_compute_dtype(DOT_HLO_INT8) == "int8"
    assert hlo_compute_dtype(DOT_HLO_BF16) == "bf16"
    assert hlo_compute_dtype("ENTRY %main { %z = f32[4]{0} add(...) }") \
        == "bf16"


def test_roofline_compute_dtype_peak():
    """An int8-dominant program's compute term divides by peak_int8 —
    the bf16 peak would overstate the compute floor 2x and bias the
    measured-latency calibration."""
    from repro.core.latency import RooflineReport
    kw = dict(flops=1e12, bytes_accessed=0.0, collective_bytes=0.0,
              per_collective={}, chips=1, hw=V5E)
    bf = RooflineReport(**kw)
    i8 = RooflineReport(compute_dtype="int8", **kw)
    assert bf.compute_peak == V5E.peak_bf16
    assert i8.compute_peak == V5E.peak_int8
    assert i8.compute_s < bf.compute_s
    assert i8.summary()["compute_dtype"] == "int8"


def test_roofline_from_compiled_dtype_paths():
    """Detection runs on the supplied HLO text (CPU XLA promotes s8 dot
    operands to s32 pre-dot, so only TPU HLO shows integer dots — the
    text/override paths are the backend-independent contract), and an
    explicit ``compute_dtype=`` always wins."""
    import jax
    import jax.numpy as jnp
    from repro.core.latency import roofline_from_compiled

    fx = jnp.ones((64, 128), jnp.float32)
    fw = jnp.ones((128, 64), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(fx, fw).compile()
    assert roofline_from_compiled(compiled).compute_dtype == "bf16"
    rep = roofline_from_compiled(compiled, hlo_text=DOT_HLO_INT8)
    assert rep.compute_dtype == "int8"
    rep = roofline_from_compiled(compiled, compute_dtype="int8")
    assert rep.compute_dtype == "int8"
    assert rep.compute_peak == V5E.peak_int8
