"""Latency-oracle properties + HLO collective parsing."""
import pytest

from repro.configs.base import ArchConfig
from repro.core.compress import lm_layer_specs
from repro.core.constraints import legalize
from repro.core.latency import (V5E, LatencyContext, hlo_collective_bytes,
                                policy_latency)
from repro.core.policy import Policy
from repro.core.spec import LayerCMP

CFG = ArchConfig(name="o", num_layers=4, d_model=256, num_heads=8,
                 num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=512)
SPECS = lm_layer_specs(CFG)
CTX = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)


def mk(mode="FP32", wb=32, ab=32, keep=1.0):
    pol = Policy([LayerCMP(keep=max(1, int(s.prune_dim * keep))
                           if s.prune_dim else 0,
                           mode=mode, w_bits=wb, a_bits=ab) for s in SPECS])
    for s, c in zip(SPECS, pol.cmps):
        legalize(s, c)
    return pol


def total(pol, ctx=CTX):
    return policy_latency(SPECS, pol, V5E, ctx).total_s


def test_quant_monotone():
    assert total(mk("INT8", 8, 8)) < total(mk("FP32"))
    assert total(mk("MIX", 4, 4)) < total(mk("INT8", 8, 8))


def test_mix6_no_better_than_int8():
    """The TPU truth the paper found on ARM: 5-6 bit MIX buys nothing."""
    assert total(mk("MIX", 6, 6)) >= total(mk("INT8", 8, 8)) * 0.999


def test_prune_monotone():
    lats = [total(mk(keep=k)) for k in (1.0, 0.5, 0.25)]
    assert lats[0] > lats[1] > lats[2]


def test_padding_staircase():
    """Kept counts within one 128-granule cost the same (MXU padding)."""
    s = [sp for sp in SPECS if sp.kind == "mlp_up"][0]
    pol_a, pol_b = mk(), mk()
    i = SPECS.index(s)
    pol_a.cmps[i] = LayerCMP(keep=257)     # pads to 384
    pol_b.cmps[i] = LayerCMP(keep=384)
    la = policy_latency(SPECS, pol_a, V5E, CTX)
    lb = policy_latency(SPECS, pol_b, V5E, CTX)
    assert la.units[i].compute_s == pytest.approx(lb.units[i].compute_s)


def test_chips_scale():
    c2 = LatencyContext(tokens=1, seq_ctx=512, mode="decode", chips=4)
    assert total(mk(), c2) < total(mk(), CTX)


def test_decode_cache_term_present():
    lat = policy_latency(SPECS, mk(), V5E, CTX)
    names = [u.name for u in lat.units]
    assert any(n.endswith(".attn") for n in names)


HLO = """
ENTRY %main {
  %ag = bf16[4,256]{1,0} all-gather(bf16[1,256]{1,0} %p0), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%add
  %ar2 = f32[64,2]{1,0} all-reduce-start(f32[64,2]{1,0} %y), to_apply=%add
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(f32[64]{0} %z, f32[64]{0} %w)
  %cp = u8[16]{0} collective-permute(u8[16]{0} %q)
  %a2a = s8[8,8]{1,0} all-to-all(s8[8,8]{1,0} %r)
}
"""


def test_hlo_collective_parse():
    out = hlo_collective_bytes(HLO)
    assert out["all-gather"] == 4 * 256 * 2
    assert out["all-reduce"] == 128 * 4 + 64 * 2 * 4
    assert out["reduce-scatter"] == 32 * 4 * 2
    assert out["collective-permute"] == 16
    assert out["all-to-all"] == 64
    assert out["_counts"]["all-reduce"] == 2
