"""The DDPG update floor (ISSUE 7): megabatched population updates vs the
``jit(vmap(update_chunk))`` parity reference, the fused MLP/Polyak kernel
routes, dispatch counting, and the paper's init distributions.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ddpg
from repro.core.ddpg import (DDPGConfig, agent_init, _mlp, _mlp_init,
                             actor_forward, critic_forward, polyak_update,
                             population_update_chunk,
                             population_update_chunk_megabatched,
                             population_update_chunk_vmap, tree_stack)
from repro.core.replay import DeviceReplay

# small nets + batch keep these tier-1 fast; shapes stay 3-layer so the
# megabatched step covers them
CFG = dict(state_dim=10, action_dim=6, hidden=(32, 24), batch_size=16)


def _population(P, mixed=False, seed=0, cap=120, fill=90, **over):
    cfg = DDPGConfig(**{**CFG, **over})
    rng = np.random.default_rng(seed)
    states, replays = [], []
    for p in range(P):
        st = agent_init(cfg, jax.random.PRNGKey(seed + p))
        n = fill - (17 * (p % 3) if mixed else 0)   # mixed sizes + ptrs
        rep = DeviceReplay(cap, cfg.state_dim, cfg.action_dim)
        for _ in range(n):
            rep.push(rng.standard_normal(cfg.state_dim).astype(np.float32),
                     rng.uniform(size=cfg.action_dim).astype(np.float32),
                     float(rng.standard_normal()),
                     rng.standard_normal(cfg.state_dim).astype(np.float32),
                     float(rng.integers(0, 2)))
        states.append(st)
        replays.append(rep.data)
    return cfg, tree_stack(states), tree_stack(replays)


def _max_err(a, b):
    errs = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)
    return max(jax.tree.leaves(errs))


# ------------------- megabatched vs vmap parity ----------------------------

@pytest.mark.parametrize("P", [1, 3, 8])
def test_megabatched_matches_vmap(P):
    cfg, states, replays = _population(P, mixed=True, seed=P)
    n = 5
    s_ref, (lc_ref, la_ref) = population_update_chunk_vmap(
        cfg, states, replays, n)
    s_mb, (lc_mb, la_mb) = population_update_chunk_megabatched(
        cfg, states, replays, n)
    assert _max_err(s_ref, s_mb) <= 1e-5
    assert float(jnp.max(jnp.abs(lc_ref - lc_mb))) <= 1e-5
    assert float(jnp.max(jnp.abs(la_ref - la_mb))) <= 1e-5
    # identical key streams -> future sampling stays bit-equal
    assert bool(jnp.all(s_ref.key == s_mb.key))


def test_megabatched_multi_chunk_stays_on_reference_trajectory():
    """Three consecutive chunks through each path stay within tolerance:
    errors don't compound past the gate."""
    cfg, states, replays = _population(4, mixed=True, seed=42)
    s_ref, s_mb = states, states
    for _ in range(3):
        s_ref, _ = population_update_chunk_vmap(cfg, s_ref, replays, 2)
        s_mb, _ = population_update_chunk_megabatched(
            cfg, s_mb, replays, 2)
    assert _max_err(s_ref, s_mb) <= 1e-4


def test_router_default_and_vmap_toggle(monkeypatch):
    """The router takes the megabatched path for the paper trunk and the
    vmap reference under GALEN_POP_UPDATE=vmap — verified by counting
    executions of each compiled entry."""
    calls = {"mega": 0, "vmap": 0}
    real_mega = ddpg._population_update_chunk_mega_jit
    real_vmap = ddpg._population_update_chunk_jit

    def count_mega(*a, **k):
        calls["mega"] += 1
        return real_mega(*a, **k)

    def count_vmap(*a, **k):
        calls["vmap"] += 1
        return real_vmap(*a, **k)

    monkeypatch.setattr(ddpg, "_population_update_chunk_mega_jit",
                        count_mega)
    monkeypatch.setattr(ddpg, "_population_update_chunk_jit", count_vmap)
    monkeypatch.delenv("GALEN_POP_UPDATE", raising=False)

    cfg, states, replays = _population(2)
    population_update_chunk(cfg, states, replays, 2)
    assert calls == {"mega": 1, "vmap": 0}

    monkeypatch.setenv("GALEN_POP_UPDATE", "vmap")
    population_update_chunk(cfg, states, replays, 2)
    assert calls == {"mega": 1, "vmap": 1}


def test_router_falls_back_for_non_paper_trunk(monkeypatch):
    """Hidden depths the hand-written step doesn't cover route to vmap."""
    calls = {"vmap": 0}
    real_vmap = ddpg._population_update_chunk_jit

    def count_vmap(*a, **k):
        calls["vmap"] += 1
        return real_vmap(*a, **k)

    monkeypatch.setattr(ddpg, "_population_update_chunk_jit", count_vmap)
    monkeypatch.delenv("GALEN_POP_UPDATE", raising=False)
    cfg, states, replays = _population(2, hidden=(32, 24, 16))
    population_update_chunk(cfg, states, replays, 1)
    assert calls["vmap"] == 1


def test_megabatched_is_one_dispatch_per_chunk(monkeypatch):
    """The whole population chunk is ONE execution of the megabatched
    compiled entry — and zero executions of the per-member/vmap ones."""
    counts = {"mega": 0, "mega_donate": 0, "vmap": 0, "member": 0}
    reals = {
        "mega": ddpg._population_update_chunk_mega_jit,
        "mega_donate": ddpg._population_update_chunk_mega_donate_jit,
        "vmap": ddpg._population_update_chunk_jit,
        "member": ddpg._update_chunk_jit,
    }

    def wrap(name):
        def f(*a, **k):
            counts[name] += 1
            return reals[name](*a, **k)
        return f

    monkeypatch.setattr(ddpg, "_population_update_chunk_mega_jit",
                        wrap("mega"))
    monkeypatch.setattr(ddpg, "_population_update_chunk_mega_donate_jit",
                        wrap("mega_donate"))
    monkeypatch.setattr(ddpg, "_population_update_chunk_jit",
                        wrap("vmap"))
    monkeypatch.setattr(ddpg, "_update_chunk_jit", wrap("member"))
    monkeypatch.delenv("GALEN_POP_UPDATE", raising=False)

    cfg, states, replays = _population(4)
    for i in range(3):
        states, _ = population_update_chunk(cfg, states, replays, 2)
        assert counts == {"mega": i + 1, "mega_donate": 0, "vmap": 0,
                          "member": 0}


def test_megabatched_donation_matches_and_consumes():
    cfg, states, replays = _population(3, mixed=True)
    ref, _ = population_update_chunk_megabatched(cfg, states, replays, 3)
    cfg2, states2, replays2 = _population(3, mixed=True)
    don, _ = population_update_chunk_megabatched(cfg2, states2, replays2, 3,
                                                 donate=True)
    assert _max_err(ref, don) == 0.0


# ----------------------- kernel-path parity --------------------------------

def test_mlp_kernel_route_matches_reference(monkeypatch):
    """GALEN_MLP_KERNEL=1 (fused Pallas forward + custom_vjp backward)
    agrees with the reference ``_mlp`` loop for both trunk shapes."""
    cfg = DDPGConfig(**CFG)
    st = agent_init(cfg, jax.random.PRNGKey(0))
    s = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.state_dim))
    a = jax.random.uniform(jax.random.PRNGKey(2), (16, cfg.action_dim))

    monkeypatch.setenv("GALEN_MLP_KERNEL", "0")
    y_ref = actor_forward(st.actor, s)
    q_ref = critic_forward(st.critic, s, a)
    ga_ref = jax.grad(lambda p: jnp.sum(actor_forward(p, s) ** 2))(st.actor)
    gc_ref = jax.grad(
        lambda p: jnp.sum(critic_forward(p, s, a) ** 2))(st.critic)

    monkeypatch.setenv("GALEN_MLP_KERNEL", "1")
    y_k = actor_forward(st.actor, s)
    q_k = critic_forward(st.critic, s, a)
    ga_k = jax.grad(lambda p: jnp.sum(actor_forward(p, s) ** 2))(st.actor)
    gc_k = jax.grad(
        lambda p: jnp.sum(critic_forward(p, s, a) ** 2))(st.critic)

    assert float(jnp.max(jnp.abs(y_k - y_ref))) <= 1e-5
    assert float(jnp.max(jnp.abs(q_k - q_ref))) <= 1e-5
    assert _max_err(ga_k, ga_ref) <= 1e-5
    assert _max_err(gc_k, gc_ref) <= 1e-5


def test_polyak_kernel_route_matches_reference(monkeypatch):
    cfg = DDPGConfig(**CFG)
    st = agent_init(cfg, jax.random.PRNGKey(3))
    monkeypatch.setenv("GALEN_MLP_KERNEL", "0")
    t_ref = polyak_update(st.target_actor, st.actor, cfg.tau)
    monkeypatch.setenv("GALEN_MLP_KERNEL", "1")
    t_k = polyak_update(st.target_actor, st.actor, cfg.tau)
    assert _max_err(t_k, t_ref) <= 1e-6


def test_mlp_route_guard_rejects_unsupported():
    """Non-3-layer, non-2D, and exotic final activations stay on the
    reference path regardless of the env toggle."""
    two = _mlp_init(jax.random.PRNGKey(0), (8, 8, 8))
    x2 = jnp.ones((4, 8))
    assert not ddpg._mlp_kernel_route(two, x2, None)
    three = _mlp_init(jax.random.PRNGKey(0), (8, 8, 8, 8))
    assert not ddpg._mlp_kernel_route(three, jnp.ones((8,)), None)
    assert not ddpg._mlp_kernel_route(three, x2, jnp.tanh)


# -------------------- init distribution properties -------------------------

def test_mlp_init_final_layer_is_paper_uniform():
    """Paper init: final layer U(-3e-3, 3e-3), hidden layers U(+-1/sqrt(a)),
    zero biases. Pinned so kernel-path refactors can't drift it."""
    dims = (10, 400, 300, 6)
    params = _mlp_init(jax.random.PRNGKey(0), dims)
    assert len(params) == 3
    for i, (l, (a, b)) in enumerate(zip(params, zip(dims[:-1], dims[1:]))):
        assert l["w"].shape == (a, b)
        assert l["b"].shape == (b,)
        assert l["w"].dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(l["b"]), 0.0)
        lim = 3e-3 if i == 2 else 1.0 / np.sqrt(a)
        w = np.asarray(l["w"])
        assert np.abs(w).max() <= lim            # bounded by the limit
        assert np.abs(w).max() >= 0.95 * lim     # and actually fills it
        assert abs(w.mean()) <= 0.1 * lim        # centered
        # uniform, not gaussian: the sample variance of U(-lim, lim) is
        # lim^2/3; a normal clipped to the same max would differ
        np.testing.assert_allclose(w.var(), lim ** 2 / 3.0, rtol=0.1)


def test_mlp_init_final_scale_only_affects_last_layer():
    p1 = _mlp_init(jax.random.PRNGKey(1), (10, 32, 24, 4),
                   final_scale=3e-3)
    p2 = _mlp_init(jax.random.PRNGKey(1), (10, 32, 24, 4),
                   final_scale=1e-1)
    for l1, l2 in zip(p1[:-1], p2[:-1]):
        np.testing.assert_array_equal(np.asarray(l1["w"]),
                                      np.asarray(l2["w"]))
    w1 = np.abs(np.asarray(p1[-1]["w"])).max()
    w2 = np.abs(np.asarray(p2[-1]["w"])).max()
    assert w1 <= 3e-3 and w2 > 3e-3


def test_agent_init_uses_paper_final_scale():
    cfg = DDPGConfig(**CFG)
    st = agent_init(cfg, jax.random.PRNGKey(4))
    for net in (st.actor, st.critic):
        assert np.abs(np.asarray(net[-1]["w"])).max() <= 3e-3
        assert np.abs(np.asarray(net[0]["w"])).max() > 3e-3


# ----------------------- regression-gate inversion -------------------------

def test_regression_gate_lower_is_better_inversion():
    """ms_per_update gates with the latency sense: UP is a regression,
    down never is. serve_tok_per_s keeps the throughput sense."""
    from benchmarks.regression_gate import check
    key = {"table": "update_floor", "engine": "megabatch", "members": 4,
           "batch_size": 128, "updates_per_episode": 8}
    base = [{**key, "ms_per_update": 10.0}]
    # 50% slower -> fails at tol 0.2
    checked, fails = check([{**key, "ms_per_update": 15.0}], base, 0.2)
    assert checked == 1 and len(fails) == 1
    # 50% faster -> passes (would have FAILED under the throughput rule)
    checked, fails = check([{**key, "ms_per_update": 5.0}], base, 0.2)
    assert checked == 1 and fails == []
    # within tolerance -> passes
    checked, fails = check([{**key, "ms_per_update": 11.0}], base, 0.2)
    assert checked == 1 and fails == []

    skey = {"table": "serve", "engine": "serve_int8", "batch_size": 4}
    sbase = [{**skey, "serve_tok_per_s": 1000.0}]
    checked, fails = check([{**skey, "serve_tok_per_s": 700.0}], sbase, 0.2)
    assert checked == 1 and len(fails) == 1
    checked, fails = check([{**skey, "serve_tok_per_s": 1500.0}], sbase,
                           0.2)
    assert checked == 1 and fails == []


def test_regression_gate_metric_filter():
    from benchmarks.regression_gate import check
    key = {"table": "update_floor", "engine": "vmap", "members": 1,
           "batch_size": 128, "updates_per_episode": 8}
    base = [{**key, "ms_per_update": 10.0, "eps_per_s": 100.0}]
    cur = [{**key, "ms_per_update": 50.0, "eps_per_s": 100.0}]
    checked, fails = check(cur, base, 0.2, metric="eps_per_s")
    assert checked == 1 and fails == []         # the bad metric is ignored
    checked, fails = check(cur, base, 0.2, metric="ms_per_update")
    assert checked == 1 and len(fails) == 1
