"""Epoch-fused engine: parity with the per-batch fused engine.

The contract under test (ISSUE 4): ``FusedCompressionSearch`` in epoch
mode (``epoch_batches=E`` / ``run_epoch``) runs E whole episode batches
— fused rollout, traced-cspec validation, reward, ``DeviceReplay`` ring
write, and the update chunk — as ONE ``jit(lax.scan)`` with donated
buffers and a single host readback, and must reproduce the per-batch
``FusedCompressionSearch`` exactly: episode records, the final
``AgentState``, and the replay ring contents.

Unlike the PR 3 parity tests, no noise replay harness is needed: the
epoch scan carries the SAME PRNG streams (the rollout key and the
agent's update-sampling key) and consumes them with the same split
pattern as the per-batch path, so two same-seed engines draw
identically by construction. The comparison therefore exercises every
stage — exploration, the in-scan normalizer advance, validation,
reward, the ring write order, and the masked in-scan update chunks
(including warmup-straddling batches, whose static update schedules
differ from the steady state).
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st

from repro.core.ddpg import DDPGConfig
from repro.core.latency import HardwareTarget, LatencyContext, V5E
from repro.core.replay import (DeviceReplay, ReplayBuffer,
                               device_replay_push)
from repro.core.reward import RewardConfig
from repro.core.search import (FusedCompressionSearch, PopulationSearch,
                               SearchConfig)


_testbed_cache = {}


def _testbed():
    """Module-cached twin of the ``tiny_lm`` fixture for the
    ``@given`` property tests (the _propcheck shim fills strategy
    parameters positionally and cannot mix with pytest fixtures)."""
    if "lm" not in _testbed_cache:
        from repro.configs.base import ArchConfig
        from repro.core.compress import CompressibleLM
        from repro.data.pipeline import bigram_lm
        from repro.models import model as M

        cfg = ArchConfig(name="t-epoch", num_layers=3, d_model=64,
                         num_heads=4, num_kv_heads=2, head_dim=16,
                         d_ff=256, vocab_size=128, scan_layers=True)
        params = M.init(cfg, jax.random.PRNGKey(0))
        batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
        _testbed_cache["lm"] = (CompressibleLM(cfg, params), batch)
    return _testbed_cache["lm"]


def _mk(tiny_lm, methods, updates=2, batch_size=4, epoch_batches=0,
        seed=0, sens=None, episodes=16, hw=V5E):
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=episodes,
        reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=updates,
                        batch_size=16, buffer_size=256), seed=seed)
    return FusedCompressionSearch(cm, batch, scfg, ctx, hw=hw, sens=sens,
                                  batch_size=batch_size,
                                  epoch_batches=epoch_batches)


def _assert_records_match(recs_a, recs_b):
    assert [r.episode for r in recs_a] == [r.episode for r in recs_b]
    for a, b in zip(recs_a, recs_b):
        assert a.reward == pytest.approx(b.reward, abs=1e-5)
        assert a.accuracy == pytest.approx(b.accuracy, abs=1e-6)
        assert a.latency_s == pytest.approx(b.latency_s, rel=1e-5)
        assert a.sigma == pytest.approx(b.sigma, abs=1e-6)
        for ca, cb in zip(a.policy.cmps, b.policy.cmps):
            assert (ca.keep, ca.mode, ca.w_bits, ca.a_bits) == \
                (cb.keep, cb.mode, cb.w_bits, cb.a_bits)


def _assert_trees_close(ta, tb, tol=2e-5):
    for la, lb in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
        np.testing.assert_allclose(np.asarray(la, np.float64),
                                   np.asarray(lb, np.float64),
                                   atol=tol, rtol=tol)


# ------------------------------------------------------- engine parity

@pytest.mark.parametrize("methods", [
    "pq",                                     # the joint agent: tier-1
    pytest.param("p", marks=pytest.mark.slow),
    pytest.param("q", marks=pytest.mark.slow),
])
def test_epoch_matches_per_batch_engine(tiny_lm, methods):
    """run() through epochs of 2 batches == per-batch fused run: records
    (reward/accuracy/latency/sigma/policies) and ring contents within
    1e-5. The first epoch straddles the agent's warmup boundary, so
    both the partial-budget and the steady update schedules are
    exercised. The final AgentState is compared at 1e-3: the engines'
    weights match op-for-op, but the running-norm stats accumulate in
    f32 on device vs f64-counted numpy on host (~1e-7 relative), and
    ~30 update GEMMs amplify that — the strict 1e-5 state bound is
    asserted update-free in ``test_epoch_state_parity_no_updates``."""
    epoch = _mk(tiny_lm, methods, epoch_batches=2)
    ref = _mk(tiny_lm, methods, sens=epoch.sens)
    res_e = epoch.run(episodes=16)
    res_r = ref.run(episodes=16)
    assert epoch.dispatch_log == ["epoch", "epoch"]
    _assert_records_match(res_e.history, res_r.history)
    assert res_e.best.episode == res_r.best.episode
    # final agent state (actor/critic/targets/Adam/norm/reward-MA/key)
    _assert_trees_close(epoch.agent.state_for_dispatch(),
                        ref.agent.state_for_dispatch(), tol=1e-3)
    # ring contents and host mirrors (rollout-side values: strict)
    assert (epoch.replay.ptr, epoch.replay.size) == \
        (ref.replay.ptr, ref.replay.size)
    _assert_trees_close(epoch.replay.data, ref.replay.data, tol=1e-5)
    # rollout PRNG stream position stayed in lockstep
    np.testing.assert_array_equal(np.asarray(epoch._rollout_key),
                                  np.asarray(ref._rollout_key))


def test_epoch_state_parity_no_updates(tiny_lm):
    """With the update amplifier off, the full final AgentState —
    norm stats included — matches the per-batch engine within 1e-5."""
    epoch = _mk(tiny_lm, "pq", updates=0, epoch_batches=2)
    ref = _mk(tiny_lm, "pq", updates=0, sens=epoch.sens)
    res_e = epoch.run(episodes=16)
    res_r = ref.run(episodes=16)
    _assert_records_match(res_e.history, res_r.history)
    _assert_trees_close(epoch.agent.state_for_dispatch(),
                        ref.agent.state_for_dispatch(), tol=1e-5)


@pytest.mark.slow
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=2, deadline=None)
def test_epoch_parity_random_seeds(seed):
    """Property form of the parity contract over agent seeds (new actor
    init, new exploration stream, new replay sampling each time)."""
    s = seed % 1000
    tb = _testbed()
    epoch = _mk(tb, "pq", epoch_batches=3, seed=s, batch_size=3)
    ref = _mk(tb, "pq", sens=epoch.sens, seed=s, batch_size=3)
    recs_e = epoch.run(episodes=9).history
    recs_r = ref.run(episodes=9).history
    _assert_records_match(recs_e, recs_r)
    _assert_trees_close(epoch.agent.state_for_dispatch(),
                        ref.agent.state_for_dispatch(), tol=1e-3)


def test_epoch_best_tracking_matches_history(tiny_lm):
    """The in-carry argmax equals the host-side best over the epoch's
    records (strict >, earliest max wins)."""
    epoch = _mk(tiny_lm, "pq", epoch_batches=4)
    recs = epoch.run_epoch(0, 4)
    best_ep, best_r = epoch.last_epoch_best
    want = max(recs, key=lambda r: r.reward)
    assert best_r == pytest.approx(want.reward, abs=1e-6)
    assert best_ep == want.episode


def test_epoch_remainder_falls_back_to_batches(tiny_lm):
    """Episode counts that don't fill an epoch run the tail through the
    per-batch fused path — same numbering, same records."""
    epoch = _mk(tiny_lm, "pq", epoch_batches=2)
    ref = _mk(tiny_lm, "pq", sens=epoch.sens)
    res_e = epoch.run(episodes=14)        # 8 (epoch) + 4 + 2 remainder
    res_r = ref.run(episodes=14)
    assert [r.episode for r in res_e.history] == list(range(14))
    _assert_records_match(res_e.history, res_r.history)
    assert "rollout" in epoch.dispatch_log   # the per-batch tail ran
    assert "epoch" in epoch.dispatch_log


def test_epoch_schedule_is_static_and_cached(tiny_lm):
    """Warmup-straddling and steady epochs compile separate executables
    (static update schedules); re-running reuses them."""
    epoch = _mk(tiny_lm, "pq", epoch_batches=2)
    assert epoch._update_schedule(0, 2) != epoch._update_schedule(8, 2)
    epoch.run(episodes=16)
    n = len(epoch._epoch_cache)
    epoch.run(episodes=16)
    assert len(epoch._epoch_cache) == n   # no new compilations


def test_epoch_dispatch_count(tiny_lm):
    """One post-compile epoch = ONE jit execution (the ISSUE 4
    acceptance bound), measured by wrapping the compiled epoch
    executables — with canaries proving the per-batch entry points
    (rollout/validate/push/update jits) and the host path never ran."""
    from benchmarks.search_setup import assert_epoch_dispatch_count
    epoch = _mk(tiny_lm, "pq", epoch_batches=2)
    epoch.run(episodes=16)               # compile both schedules
    counts = assert_epoch_dispatch_count(epoch, first_episode=8,
                                         n_batches=2)
    assert counts == {"epoch": 1, "rollout": 0, "validate": 0,
                      "push": 0, "update": 0, "host_steps": 0}


# ---------------------------------------------------- epoch populations

@pytest.mark.slow
def test_population_epoch_matches_solo(tiny_lm):
    """One vmapped epoch dispatch across hardware targets reproduces
    each member run alone (same seeds -> same PRNG streams)."""
    v5p = HardwareTarget(name="tpu-v5p", peak_bf16=459e12,
                         peak_int8=918e12, hbm_bw=2765e9, ici_bw=90e9)

    def member(hw, sens=None):
        return _mk(tiny_lm, "pq", batch_size=3, epoch_batches=2,
                   sens=sens, hw=hw)

    m0 = member(V5E)
    pop = PopulationSearch([member(V5E, sens=m0.sens),
                            member(v5p, sens=m0.sens)],
                           fuse_rollouts=True)
    assert pop._epochs_fusable()
    results = pop.run(episodes=12)
    for m in pop.members:
        assert m.dispatch_log == ["epoch", "epoch"]
    solos = [member(V5E, sens=m0.sens), member(v5p, sens=m0.sens)]
    for m, res in zip(solos, results):
        want = m.run(episodes=12)
        _assert_records_match(res.history, want.history)


def test_population_epoch_requires_shared_reward(tiny_lm):
    """Members whose epoch traces can't be shared (here: different
    reward configs, which bake into the trace) fall back to per-member
    epoch dispatches — same batch decomposition, still one execution
    per member per epoch."""
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)

    def member(c, sens=None):
        scfg = SearchConfig(
            methods="pq", episodes=4, reward=RewardConfig(target_ratio=c),
            ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                            batch_size=16, buffer_size=256))
        return FusedCompressionSearch(cm, batch, scfg, ctx, sens=sens,
                                      batch_size=2, epoch_batches=2)

    m0 = member(0.5)
    pop = PopulationSearch([m0, member(0.6, sens=m0.sens)],
                           fuse_rollouts=True)
    assert pop._rollouts_fusable() and not pop._epochs_fusable()
    results = pop.run(episodes=4)
    for m, res in zip(pop.members, results):
        assert m.dispatch_log == ["epoch"]
        assert [r.episode for r in res.history] == list(range(4))
        assert all(np.isfinite(rec.reward) for rec in res.history)


# ------------------------------------------------- pure ring push

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_device_replay_push_matches_host_reference(seed):
    """The pure scan-safe ring write == the host ReplayBuffer reference
    across wraps and oversized batches."""
    rng = np.random.default_rng(seed)
    cap, sd, ad = 16, 3, 2
    host = ReplayBuffer(cap, sd, ad)
    dev = DeviceReplay(cap, sd, ad)
    data = dev.data
    ptr = size = 0
    for _ in range(4):
        n = int(rng.integers(1, 2 * cap))
        s = rng.random((n, sd)).astype(np.float32)
        a = rng.random((n, ad)).astype(np.float32)
        r = rng.random(n).astype(np.float32)
        s2 = rng.random((n, sd)).astype(np.float32)
        d = (rng.random(n) < 0.1).astype(np.float32)
        host.push_batch(s, a, r, s2, d)
        data = device_replay_push(data, s, a, r, s2, d)
        ptr, size = (ptr + n) % cap, min(size + n, cap)
    assert (int(data.ptr), int(data.size)) == (host.ptr, host.size)
    assert (ptr, size) == (host.ptr, host.size)
    np.testing.assert_allclose(np.asarray(data.states), host.states)
    np.testing.assert_allclose(np.asarray(data.actions), host.actions)
    np.testing.assert_allclose(np.asarray(data.rewards), host.rewards)
    np.testing.assert_allclose(np.asarray(data.next_states),
                               host.next_states)
    np.testing.assert_allclose(np.asarray(data.dones), host.dones)
