"""End-to-end behaviour tests for the Galen system (paper-level claims at
unit-test scale; the full claims are validated in benchmarks/)."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import CompressibleResNet
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext, policy_latency
from repro.core.policy import Policy
from repro.core.reward import RewardConfig
from repro.core.search import CompressionSearch, SearchConfig
from repro.core.spec import LayerCMP


def test_joint_policy_end_to_end(tiny_lm):
    """Full pipeline: sensitivity -> episodes -> best policy applies and
    evaluates; compressed latency below reference."""
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(methods="pq", episodes=8,
                        reward=RewardConfig(target_ratio=0.5, beta=-3.0),
                        ddpg=DDPGConfig(warmup_episodes=4,
                                        updates_per_episode=4,
                                        batch_size=16, buffer_size=512))
    search = CompressionSearch(cm, batch, scfg, ctx)
    res = search.run()
    best = res.best
    assert best is not None
    # the found policy must actually compress (latency below reference)
    assert best.latency_s < res.ref_latency_s
    # and still produce a valid model
    cs = cm.build_cspec(best.policy)
    acc = float(cm.accuracy(batch, cs))
    assert 0.0 <= acc <= 1.0


def test_resnet_policy_applies(tiny_resnet):
    """The paper's own testbed family goes through the same machinery."""
    cm, batch = tiny_resnet
    pol = Policy.reference(cm.specs)
    for i, s in enumerate(cm.specs):
        if s.prunable and s.prune_dim >= 16:
            pol.cmps[i] = LayerCMP(keep=8, mode="INT8", w_bits=8, a_bits=8)
    cs = cm.build_cspec(pol)
    acc = float(cm.accuracy(batch, cs))
    assert 0.0 <= acc <= 1.0
    ctx = LatencyContext(tokens=1, seq_ctx=0, mode="prefill", batch=1)
    lat_c = policy_latency(cm.specs, pol, ctx=ctx).total_s
    lat_r = policy_latency(cm.specs, Policy.reference(cm.specs),
                           ctx=ctx).total_s
    assert lat_c < lat_r


def test_macs_bops_reported(tiny_lm):
    """Table-1 metrics (MACs / BOPs / latency / accuracy) all derivable."""
    cm, batch = tiny_lm
    pol = Policy([LayerCMP(keep=s.prune_dim, mode="INT8", w_bits=8,
                           a_bits=8) for s in cm.specs])
    macs = pol.macs_fraction(cm.specs)
    bops = pol.bops(cm.specs)
    assert macs == pytest.approx(1.0)
    assert bops > 0


def test_qat_retraining_recovers_accuracy(tiny_lm):
    """Paper: compressed models are retrained (30 epochs). Mechanism test:
    QAT train step with a cspec threads fake-quant and reduces loss."""
    from repro.optim.optimizer import OptimizerConfig, adamw_init
    from repro.train.train_step import make_train_step

    cm, batch = tiny_lm
    pol = Policy([LayerCMP(keep=s.prune_dim, mode="MIX", w_bits=3, a_bits=4)
                  for s in cm.specs])
    cs = cm.build_cspec(pol)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=30,
                           weight_decay=0.0)
    params = cm.params
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(cm.cfg, ocfg, cspec=cs))
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
