import os
import sys

# Tests must see ONE device (the dry-run sets its own flags in a fresh
# process). Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_lm():
    """Small untrained LM + batch for mechanics tests (fast)."""
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.core.compress import CompressibleLM
    from repro.data.pipeline import bigram_lm
    from repro.models import model as M

    cfg = ArchConfig(name="t", num_layers=3, d_model=64, num_heads=4,
                     num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=128,
                     scan_layers=True)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
    return CompressibleLM(cfg, params), batch


@pytest.fixture(scope="session")
def tiny_resnet():
    from repro.core.compress import CompressibleResNet
    from repro.data.pipeline import blob_images
    from repro.models import resnet as R

    cfg = R.ResNetConfig(stages=(1, 1), widths=(8, 16), img_size=8,
                         num_classes=4)
    params = R.init(cfg, jax.random.PRNGKey(0))
    batch = blob_images(4, 16, 8, seed=5)
    return CompressibleResNet(cfg, params), batch
