import os
import sys

# Tests must see ONE device (the dry-run sets its own flags in a fresh
# process). Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def require_devices(n: int):
    """Skip (with the forced-host-device recipe in the reason) when the
    current process has fewer than ``n`` local devices. Mesh-size-gated
    tests call this first: they skip in the ordinary 1-device suite and
    run in CI's dedicated multi-device step, which launches a fresh
    pytest process under ``XLA_FLAGS=--xla_force_host_platform_device_
    count=8`` (the flag only works before jax first initializes)."""
    have = len(jax.devices())
    if have < n:
        pytest.skip(
            f"needs {n} local devices, this process has {have}; run in a "
            f"fresh process under XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n}")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_lm():
    """Small untrained LM + batch for mechanics tests (fast)."""
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.core.compress import CompressibleLM
    from repro.data.pipeline import bigram_lm
    from repro.models import model as M

    cfg = ArchConfig(name="t", num_layers=3, d_model=64, num_heads=4,
                     num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=128,
                     scan_layers=True)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
    return CompressibleLM(cfg, params), batch


@pytest.fixture(scope="session")
def tiny_resnet():
    from repro.core.compress import CompressibleResNet
    from repro.data.pipeline import blob_images
    from repro.models import resnet as R

    cfg = R.ResNetConfig(stages=(1, 1), widths=(8, 16), img_size=8,
                         num_classes=4)
    params = R.init(cfg, jax.random.PRNGKey(0))
    batch = blob_images(4, 16, 8, seed=5)
    return CompressibleResNet(cfg, params), batch
