"""DDPG agent learning tests on synthetic control problems."""
import numpy as np
import pytest

from repro.core.ddpg import DDPGAgent, DDPGConfig, RunningNorm
from repro.core.replay import ReplayBuffer


def test_replay_circular():
    buf = ReplayBuffer(4, 2, 1)
    for i in range(6):
        buf.push([i, i], [i], float(i), [i + 1, i + 1], i == 5)
    assert len(buf) == 4
    s, a, r, s2, d = buf.sample(8)
    assert s.shape == (8, 2)
    assert set(np.unique(r)) <= {2.0, 3.0, 4.0, 5.0}  # oldest evicted


def test_running_norm():
    rn = RunningNorm(3)
    data = np.random.default_rng(0).normal(5.0, 2.0, (500, 3)).astype(
        np.float32)
    for i in range(0, 500, 50):
        rn.update(data[i:i + 50])
    np.testing.assert_allclose(rn.mean, 5.0, atol=0.3)
    np.testing.assert_allclose(np.sqrt(rn.var), 2.0, atol=0.3)
    z = rn.normalize(data)
    assert abs(z.mean()) < 0.1


def test_agent_learns_bandit():
    """1-step continuous bandit: reward = -(a - 0.7)^2. The actor should
    move toward 0.7."""
    cfg = DDPGConfig(state_dim=2, action_dim=1, hidden=(32, 32),
                     batch_size=32, buffer_size=512, warmup_episodes=0,
                     actor_lr=1e-3, critic_lr=1e-2, gamma=0.0)
    agent = DDPGAgent(cfg, seed=0)
    buf = ReplayBuffer(512, 2, 1, seed=0)
    rng = np.random.default_rng(0)
    s = np.zeros(2, np.float32)
    for i in range(256):
        a = rng.uniform(0, 1, 1).astype(np.float32)
        r = -(float(a[0]) - 0.7) ** 2
        buf.push(s, a, r, s, True)
    agent.observe_states(np.zeros((4, 2), np.float32))
    for _ in range(300):
        agent.update(buf)
    a_final = agent.act(s, sigma=0.0)
    assert abs(float(a_final[0]) - 0.7) < 0.15


def test_sigma_decay():
    cfg = DDPGConfig(warmup_episodes=5, sigma0=0.5, sigma_decay=0.9)
    agent = DDPGAgent(cfg, seed=0)
    assert agent.sigma_at(0) == pytest.approx(0.5)   # during warmup
    assert agent.sigma_at(5) == pytest.approx(0.5)
    assert agent.sigma_at(15) == pytest.approx(0.5 * 0.9 ** 10)


def test_actions_bounded():
    cfg = DDPGConfig(state_dim=4, action_dim=3)
    agent = DDPGAgent(cfg, seed=1)
    for sigma in (0.0, 0.3, 1.0):
        a = agent.act(np.random.randn(4).astype(np.float32), sigma)
        assert a.shape == (3,)
        assert (a >= 0).all() and (a <= 1).all()
