"""Property tests for the continuous->discrete policy mapping (Eq. 4/8)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st

from repro.core import constraints
from repro.core.policy import (T_INT8, T_MIX, Policy, d_inverse, map_actions,
                               prune_keep_from_action, quant_cmp_from_actions,
                               scale_mix_action)
from repro.core.spec import LayerCMP, LayerSpec


def spec(prune_dim=512, gran=128, in_dim=512, mix=True, prunable=True):
    return LayerSpec(name="u", kind="mlp_up", layer_idx=0, in_dim=in_dim,
                     out_dim=prune_dim, prunable=prunable,
                     prune_dim=prune_dim, prune_granularity=gran,
                     quantizable=True, mix_supported=mix,
                     flops_per_token=1.0, weight_elems=in_dim * prune_dim,
                     act_elems_per_token=in_dim)


@given(st.floats(0, 1), st.integers(1, 4096))
def test_d_inverse_bounds(r, v):
    out = d_inverse(r, v)
    assert 1 <= out <= v + 1
    assert d_inverse(1.0, v) == 1            # max compression -> 1 unit


@given(st.floats(0, 1), st.floats(0, 1), st.integers(8, 2048))
def test_d_inverse_monotone(r1, r2, v):
    lo, hi = min(r1, r2), max(r1, r2)
    assert d_inverse(hi, v) <= d_inverse(lo, v)


@given(st.floats(0, 1), st.floats(0, 1))
def test_quant_mode_thresholds(aw, aa):
    cmp = quant_cmp_from_actions(aw, aa)
    if max(aw, aa) > T_MIX:
        assert cmp.mode == "MIX"
        assert 1 <= cmp.w_bits <= 6 and 1 <= cmp.a_bits <= 6
    elif max(aw, aa) > T_INT8:
        assert cmp.mode == "INT8" and cmp.w_bits == 8
    else:
        assert cmp.mode == "FP32" and cmp.w_bits == 32


def test_mix_extremes():
    # action just above threshold -> weakest MIX (6 bits); action 1 -> 1 bit
    assert quant_cmp_from_actions(0.5001, 0.0).w_bits == 6
    assert quant_cmp_from_actions(1.0, 1.0).w_bits == 1
    assert scale_mix_action(0.5) == 0.0
    assert scale_mix_action(1.0) == 1.0


@given(st.floats(0, 1))
def test_legalize_granularity(a):
    s = spec(prune_dim=512, gran=128)
    cmp = map_actions(s, [a, 0.0, 0.0], "pq")
    assert cmp.keep % 128 == 0
    assert 128 <= cmp.keep <= 512


def test_legalize_mix_fallback():
    # in_dim not 256-aligned and not conv -> MIX illegal -> INT8
    s = spec(in_dim=100)
    cmp = map_actions(s, [0.0, 0.9, 0.9], "pq")
    assert cmp.mode == "INT8"


def test_non_prunable_keeps_all():
    s = spec(prunable=False)
    cmp = map_actions(s, [1.0], "p")
    assert cmp.keep == s.prune_dim


def test_policy_macs_bops():
    specs = [spec(), spec()]
    ref = Policy.reference(specs)
    assert ref.macs_fraction(specs) == pytest.approx(1.0)
    half = Policy([LayerCMP(keep=256), LayerCMP(keep=512)])
    assert half.macs_fraction(specs) == pytest.approx(0.75)
    # BOPs: int8 policy is 16x fewer BOPs than fp32
    p32 = Policy([LayerCMP(keep=512, mode="FP32", w_bits=32, a_bits=32)] * 2)
    p8 = Policy([LayerCMP(keep=512, mode="INT8", w_bits=8, a_bits=8)] * 2)
    assert p32.bops(specs) / p8.bops(specs) == pytest.approx(16.0)
