"""Fused rollout engine: parity with the numpy batched engine.

The contract under test (ISSUE 3): ``FusedCompressionSearch`` runs the
whole episode environment — oracle features, actor with in-scan PRNG
exploration, action->CMP projection, policy carry — as ONE
``jit(lax.scan)``, and must reproduce ``BatchedCompressionSearch``
step for step: states, actions, final ``PolicyBatch``, rewards.

Exploration randomness is replayed through the numpy reference engine
via the fused path's exposed per-batch key (the same idiom as PR 2's
``chunk_sample_keys``), so the comparison exercises every
deterministic stage: the jnp oracle vs the f64 numpy oracle, the
static/decided state features, the vectorized Eq. 4/8 mapping +
legalization, and the reward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st

from repro.configs.base import ArchConfig
from repro.core import latency as latency_mod
from repro.core import state as state_mod
from repro.core.compress import lm_layer_specs
from repro.core.constraints import legal_tables
from repro.core.ddpg import DDPGAgent, DDPGConfig, agent_act_batch
from repro.core.latency import (V5E, HardwareTarget, JaxBatchOracle,
                                LatencyContext, get_batch_oracle,
                                policy_latency_batch)
from repro.core.policy import (Policy, action_columns, map_actions,
                               map_actions_batch, n_actions,
                               policies_from_batch, stack_policies)
from repro.core.reward import RewardConfig
from repro.core.search import (BatchedCompressionSearch,
                               FusedCompressionSearch, PopulationSearch,
                               SearchConfig)
from repro.core.spec import effective_bits

CFG = ArchConfig(name="o", num_layers=4, d_model=256, num_heads=8,
                 num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=512)
SPECS = lm_layer_specs(CFG)
CTX = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)
CTXS = (CTX,
        LatencyContext(tokens=128, seq_ctx=512, mode="prefill", tp=4,
                       chips=4),
        LatencyContext(tokens=4, seq_ctx=0, mode="train"))


def rand_policy(rng) -> Policy:
    return Policy([map_actions(s, rng.random(3), "pq") for s in SPECS])


# ------------------------------------------------------- action mapping

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_map_actions_batch_matches_scalar(seed):
    """Array mapping == scalar map_actions (+legalize) element for
    element, on every spec, for every method's live fields."""
    rng = np.random.default_rng(seed)
    lt = legal_tables(SPECS)
    for methods in ("p", "q", "pq"):
        ip, iw, ia = action_columns(methods)
        A = rng.random((8, n_actions(methods))).astype(np.float32)
        for i, s in enumerate(SPECS):
            keep, wb, ab = (np.asarray(x) for x in map_actions_batch(
                A, prune_dim=lt.prune_dim[i],
                granularity=lt.granularity[i], prunable=lt.prunable[i],
                quantizable=lt.quantizable[i], mix_ok=lt.mix_ok[i],
                ip=ip, iw=iw, ia=ia))
            for j in range(A.shape[0]):
                cmp = map_actions(s, A[j], methods)
                want_wb, want_ab = effective_bits(cmp)
                if "p" in methods:
                    assert keep[j] == cmp.keep, (methods, s.name, A[j])
                if "q" in methods:
                    assert (wb[j], ab[j]) == (want_wb, want_ab), \
                        (methods, s.name, A[j])


def test_policies_from_batch_roundtrip():
    rng = np.random.default_rng(5)
    pols = [rand_policy(rng) for _ in range(4)]
    back = policies_from_batch(SPECS, stack_policies(SPECS, pols))
    for p, q in zip(pols, back):
        for a, b in zip(p.cmps, q.cmps):
            assert (a.keep, effective_bits(a)) == (b.keep,
                                                   effective_bits(b))
            assert a.mode == b.mode


# -------------------------------------------------------- the jnp oracle

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_jax_oracle_matches_numpy(seed):
    """JaxBatchOracle == BatchOracle per unit/extra/total (f32 drift
    only), all contexts, plus the in-scan decided_before bookkeeping."""
    rng = np.random.default_rng(seed)
    pols = [rand_policy(rng) for _ in range(5)]
    pb = stack_policies(SPECS, pols)
    for ctx in CTXS:
        want = get_batch_oracle(SPECS, V5E, ctx)(pb)
        jo = JaxBatchOracle(SPECS, V5E, ctx)
        ut, et = jo.unit_times(pb.keep, pb.w_bits, pb.a_bits)
        np.testing.assert_allclose(np.asarray(ut), want.unit_time_s,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(et), want.extra_time_s,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(jo.totals(ut, et)),
                                   want.total_s, rtol=1e-5)
        for t in (0, len(SPECS) // 2, len(SPECS)):
            np.testing.assert_allclose(
                np.asarray(jo.decided_before(ut, et, t)),
                want.decided_before(t), rtol=1e-5, atol=1e-12)


def test_jax_oracle_hwp_vmaps_over_targets():
    """One traced oracle serves a stacked HwParams pytree — the
    multi-target rollout's vectorization axis."""
    from repro.core.latency import hw_params
    rng = np.random.default_rng(3)
    pb = stack_policies(SPECS, [rand_policy(rng) for _ in range(3)])
    v5p = HardwareTarget(name="tpu-v5p", peak_bf16=459e12,
                         peak_int8=918e12, hbm_bw=2765e9, ici_bw=90e9)
    jo = JaxBatchOracle(SPECS, V5E, CTX)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), hw_params(V5E),
                           hw_params(v5p))
    totals = jax.vmap(
        lambda hwp: jo.totals(*jo.unit_times(pb.keep, pb.w_bits,
                                             pb.a_bits, hwp), hwp))(stacked)
    for hw, got in zip((V5E, v5p), np.asarray(totals)):
        want = policy_latency_batch(SPECS, pb, hw, CTX).total_s
        np.testing.assert_allclose(got, want, rtol=1e-5)


# --------------------------------------------------------- in-scan actor

def test_agent_act_batch_bounds_and_sigma_zero():
    cfg = DDPGConfig(state_dim=8, action_dim=3)
    agent = DDPGAgent(cfg, seed=0)
    states = np.random.default_rng(0).random((6, 8)).astype(np.float32)
    key = jax.random.PRNGKey(1)
    a = np.asarray(agent_act_batch(
        cfg, agent.state, jnp.asarray(states), key,
        jnp.full(6, 0.5, jnp.float32), jnp.zeros(6, bool)))
    assert a.shape == (6, 3) and np.all((a >= 0) & (a <= 1))
    warm = np.asarray(agent_act_batch(
        cfg, agent.state, jnp.asarray(states), key,
        jnp.full(6, 0.5, jnp.float32), jnp.ones(6, bool)))
    assert np.all((warm >= 0) & (warm < 1))
    # sigma=0 is the deterministic actor — must match the host path
    det = np.asarray(agent_act_batch(
        cfg, agent.state, jnp.asarray(states), key,
        jnp.zeros(6, jnp.float32), jnp.zeros(6, bool)))
    host = agent.act_batch(states, np.zeros(6), np.zeros(6, bool))
    np.testing.assert_allclose(det, host, atol=1e-5)


# ------------------------------------------------------- engine parity

def _mk(tiny_lm, cls, methods, updates=0, batch_size=4, seed=0,
        sens=None):
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=8, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=updates,
                        batch_size=16, buffer_size=256), seed=seed)
    return cls(cm, batch, scfg, ctx, sens=sens, batch_size=batch_size)


@pytest.mark.parametrize("methods", ["p", "q", "pq"])
def test_fused_rollout_matches_batched_engine(tiny_lm, methods):
    """States, actions, final PolicyBatch, and rewards within 1e-5 of
    the numpy engine when the numpy engine replays the fused path's
    exact exploration draws — one batch straddling warmup, one fully
    live (norm stats advanced across the boundary)."""
    K = 4
    fused = _mk(tiny_lm, FusedCompressionSearch, methods)
    ref = _mk(tiny_lm, BatchedCompressionSearch, methods,
              sens=fused.sens)
    for first in (0, K):
        args = fused._rollout_args(first, K)
        st_snap = args[0]                 # agent state the scan consumed
        out = fused._rollout(*args)
        recs_f = fused._finish_batch(first, K, out)

        keys = iter(jax.random.split(fused._last_batch_key,
                                     len(fused.steps)))
        captured = []

        def act_replay(S, sigmas, warm):
            A = np.asarray(agent_act_batch(
                ref.agent.cfg, st_snap, jnp.asarray(S, jnp.float32),
                next(keys), jnp.asarray(sigmas, jnp.float32),
                jnp.asarray(warm)))
            captured.append((np.asarray(S, np.float32).copy(), A))
            return A

        ref.agent.act_batch = act_replay
        recs_r = ref.run_episode_batch(first, K)

        S_f, A_f = np.asarray(out[3]), np.asarray(out[4])
        S_r = np.stack([c[0] for c in captured])
        A_r = np.stack([c[1] for c in captured])
        np.testing.assert_allclose(S_f, S_r, atol=1e-5)
        np.testing.assert_allclose(A_f, A_r, atol=1e-5)
        pb_f = stack_policies(fused.specs, [r.policy for r in recs_f])
        pb_r = stack_policies(ref.specs, [r.policy for r in recs_r])
        np.testing.assert_array_equal(pb_f.keep, pb_r.keep)
        np.testing.assert_array_equal(pb_f.w_bits, pb_r.w_bits)
        np.testing.assert_array_equal(pb_f.a_bits, pb_r.a_bits)
        for a, b in zip(recs_f, recs_r):
            assert a.reward == pytest.approx(b.reward, abs=1e-5)
            assert a.accuracy == pytest.approx(b.accuracy, abs=1e-6)
            assert a.latency_s == pytest.approx(b.latency_s, rel=1e-5)
            assert a.sigma == pytest.approx(b.sigma, abs=1e-6)


@pytest.mark.parametrize("methods", ["p", "q", "pq"])
def test_fused_search_runs_all_agents(tiny_lm, methods):
    """End-to-end engine smoke: episode numbering, legality, replay
    fill, finite records — with live update dispatches."""
    search = _mk(tiny_lm, FusedCompressionSearch, methods, updates=2)
    res = search.run(episodes=8)
    assert [r.episode for r in res.history] == list(range(8))
    for rec in res.history:
        assert np.isfinite(rec.reward)
        assert 0.0 <= rec.accuracy <= 1.0
        assert rec.latency_s > 0
        for s, c in zip(search.specs, rec.policy.cmps):
            if s.prunable and s.prune_dim:
                assert c.keep % s.prune_granularity == 0 \
                    or c.keep == s.prune_dim
            if c.mode == "MIX":
                assert s.mix_supported
            if not s.quantizable:
                assert c.mode == "FP32"
    assert len(search.replay) == min(256, 8 * len(search.steps))


def test_fused_dispatch_count(tiny_lm):
    """One episode batch = rollout + validation + ring write + update
    chunk: <= 4 jit executions on the fused path (the ISSUE 3
    acceptance bound), measured by wrapping the compiled entry points
    themselves — with canaries proving the per-step host path is gone."""
    from benchmarks.search_setup import assert_fused_dispatch_count
    search = _mk(tiny_lm, FusedCompressionSearch, "pq", updates=2)
    search.run(episodes=8)               # compile + cross warmup
    counts = assert_fused_dispatch_count(search, first_episode=8,
                                         batch_size=4)
    assert counts == {"rollout": 1, "validate": 1, "push": 1,
                      "update": 1, "host_steps": 0}
    assert search.dispatch_log == ["rollout", "validate", "push",
                                   "update"]


# ---------------------------------------------------- fused populations

def test_population_fused_rollouts_match_solo(tiny_lm):
    """fuse_rollouts=True: one vmapped rollout across hardware targets
    reproduces each member run alone (same seeds -> same PRNG)."""
    v5p = HardwareTarget(name="tpu-v5p", peak_bf16=459e12,
                         peak_int8=918e12, hbm_bw=2765e9, ici_bw=90e9)
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods="pq", episodes=6, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=16, buffer_size=256))

    def member(hw, sens=None):
        return FusedCompressionSearch(cm, batch, scfg, ctx, hw=hw,
                                      sens=sens, batch_size=3)

    m0 = member(V5E)
    members = [member(V5E, sens=m0.sens), member(v5p, sens=m0.sens)]
    pop = PopulationSearch(members, fuse_rollouts=True)
    assert pop._rollouts_fusable()
    results = pop.run(episodes=6)
    solos = [member(V5E, sens=m0.sens), member(v5p, sens=m0.sens)]
    for m, res in zip(solos, results):
        want = m.run(episodes=6)
        for a, b in zip(res.history, want.history):
            assert a.reward == pytest.approx(b.reward, abs=1e-6)
            assert a.latency_s == pytest.approx(b.latency_s, rel=1e-6)
            assert a.accuracy == pytest.approx(b.accuracy, abs=1e-6)


def test_population_mixed_methods_falls_back(tiny_lm):
    """Mixed p/q/pq members have different step lists — the population
    keeps per-member (still fused) rollouts and shared updates."""
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)

    def member(methods):
        scfg = SearchConfig(
            methods=methods, episodes=4,
            reward=RewardConfig(target_ratio=0.5),
            ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                            batch_size=16, buffer_size=256, action_dim=3))
        return FusedCompressionSearch(cm, batch, scfg, ctx, batch_size=2)

    pop = PopulationSearch([member("p"), member("q"), member("pq")],
                           fuse_rollouts=True)
    assert not pop._rollouts_fusable()
    results = pop.run(episodes=4)
    assert len(results) == 3
    for res in results:
        assert [r.episode for r in res.history] == list(range(4))
        assert all(np.isfinite(r.reward) for r in res.history)


# --------------------------------------------------- cache eviction

def test_oracle_cache_evicts_oldest(monkeypatch):
    monkeypatch.setattr(latency_mod, "_ORACLE_CACHE_MAX", 2)
    monkeypatch.setattr(latency_mod, "_oracle_cache", {})
    spec_lists = [lm_layer_specs(CFG) for _ in range(3)]
    oracles = [get_batch_oracle(s, V5E, CTX) for s in spec_lists]
    cache = latency_mod._oracle_cache
    assert len(cache) == 2
    # oldest entry (spec_lists[0]) evicted; newest two retained
    assert get_batch_oracle(spec_lists[1], V5E, CTX) is oracles[1]
    assert get_batch_oracle(spec_lists[2], V5E, CTX) is oracles[2]
    assert all(hit.specs is not spec_lists[0] for hit in cache.values())


def test_static_cache_evicts_oldest(tiny_lm, monkeypatch):
    cm, _ = tiny_lm
    monkeypatch.setattr(state_mod, "_STATIC_CACHE_MAX", 2)
    monkeypatch.setattr(state_mod, "_static_cache", {})
    search = _mk(tiny_lm, BatchedCompressionSearch, "pq")
    from repro.core.state import _static_features
    vals = [_static_features(search.specs, t, search.sens, search.ref_lat)
            for t in search.steps[:3]]
    cache = state_mod._static_cache
    assert len(cache) == 2
    keys = list(cache)
    # the two newest steps survive; re-reading them is a hit (identity)
    assert _static_features(search.specs, search.steps[1], search.sens,
                            search.ref_lat) is vals[1]
    assert list(cache) == keys
