"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quant_matmul import quant_matmul


# --------------------------- quant matmul ----------------------------------

@pytest.mark.parametrize("M,K,N", [(64, 128, 64), (200, 300, 130),
                                   (256, 256, 256), (33, 512, 257)])
def test_int8_matmul_matches_int_ref(M, K, N):
    x = jax.random.normal(jax.random.PRNGKey(M), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(N), (K, N))
    y = ops.quantized_matmul(x, w, w_bits=8)
    xq, sx, zx = ref.quantize_rows(x, 8)
    wq, sw, zw = ref.quantize_cols(w, 8)
    yr = ref.int8_matmul_ref(xq, wq, sx, zx, sw, zw)
    # int32 accumulation is exact; the f32 zero-point correction sums can
    # exceed 2^24 so kernel/ref may differ by f32 association noise.
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=0.1)


@pytest.mark.parametrize("M,K,N", [(64, 128, 64), (100, 256, 96)])
def test_int8_matmul_close_to_f32(M, K, N):
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    y = ops.quantized_matmul(x, w, w_bits=8)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.03


def test_int4_matmul():
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 256))
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 128))
    y = ops.quantized_matmul(x, w, w_bits=4)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.2  # 4-bit weights on gaussian data


def test_int4_pack_unpack_roundtrip():
    w4 = jax.random.randint(jax.random.PRNGKey(4), (64, 32), -8, 8) \
        .astype(jnp.int8)
    assert bool(jnp.all(ref.unpack_int4_ref(ref.pack_int4(w4)) == w4))


def test_quant_matmul_block_shapes():
    """Kernel correct for several BlockSpec tilings."""
    M = K = N = 512
    x = jax.random.normal(jax.random.PRNGKey(5), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(6), (K, N))
    xq, sx, zx = ref.quantize_rows(x, 8)
    wq, sw, zw = ref.quantize_cols(w, 8)
    yr = ref.int8_matmul_ref(xq, wq, sx, zx, sw, zw)
    for bm, bk, bn in [(128, 128, 128), (256, 512, 128), (512, 256, 256)]:
        y = quant_matmul(xq, wq, sx, zx, sw, zw, bm=bm, bk=bk, bn=bn,
                         interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                                   atol=1e-3)


def test_asymmetric_zero_point_convention():
    """Locks the ADD convention x = s·(q + z) end to end: strongly
    shifted (non-zero-mean) data makes the zero-point correction terms
    large, so any sign error in the epilogue is a gross miss. Kernel,
    integer-accumulation ref and dequantize-then-matmul ground truth
    must all agree, and all must approximate the f32 matmul."""
    M, K, N = 64, 128, 96
    x = jax.random.normal(jax.random.PRNGKey(20), (M, K)) + 3.0
    w = jax.random.normal(jax.random.PRNGKey(21), (K, N)) - 1.0
    xq, sx, zx = ref.quantize_rows(x, 8)
    wq, sw, zw = ref.quantize_cols(w, 8)
    want = ref.dequant_matmul_ref(xq, wq, sx, zx, sw, zw)
    got_ref = ref.int8_matmul_ref(xq, wq, sx, zx, sw, zw)
    got_kern = quant_matmul(xq, wq, sx, zx, sw, zw, interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-3, atol=0.1)
    np.testing.assert_allclose(np.asarray(got_kern), np.asarray(want),
                               rtol=1e-3, atol=0.1)
    fp = x @ w
    rel = float(jnp.linalg.norm(want - fp) / jnp.linalg.norm(fp))
    assert rel < 0.03
    # the test has teeth: SUBTRACT-convention zero points miss badly
    wrong = ref.int8_matmul_ref(xq, wq, sx, -zx, sw, -zw)
    rel_wrong = float(jnp.linalg.norm(wrong - fp) / jnp.linalg.norm(fp))
    assert rel_wrong > 10 * rel


@pytest.mark.parametrize("M,K,N", [(64, 128, 64), (32, 256, 96)])
def test_quant_matmul_packed_matches_ref(M, K, N):
    """packed=True consumes ``ref.pack_int4`` nibbles and must equal the
    dequantize-then-matmul ground truth of the unpacked codes (tight),
    and stay within int4 noise of the f32 matmul (loose)."""
    x = jax.random.normal(jax.random.PRNGKey(M + 40), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(N + 41), (K, N))
    xq, sx, zx = ref.quantize_rows(x, 8)
    wq, sw, zw = ref.quantize_cols(w, 4)
    y = quant_matmul(xq, ref.pack_int4(wq), sx, zx, sw, zw,
                     packed=True, interpret=True)
    want = ref.dequant_matmul_ref(xq, wq, sx, zx, sw, zw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-3, atol=0.1)
    fp = x @ w
    rel = float(jnp.linalg.norm(y - fp) / jnp.linalg.norm(fp))
    assert rel < 0.2


def test_quant_matmul_packed_k_true():
    """Zero-padding K must not corrupt the K·zx·zw zero-point term:
    with ``k_true`` the padded kernel reproduces the unpadded ground
    truth exactly (padded q codes contribute nothing to acc or the
    row/col sums; only the K count needs correcting)."""
    M, K_true, K, N = 32, 300, 512, 64
    x = jax.random.normal(jax.random.PRNGKey(50), (M, K_true)) + 1.0
    w = jax.random.normal(jax.random.PRNGKey(51), (K_true, N))
    xq, sx, zx = ref.quantize_rows(x, 8)
    wq, sw, zw = ref.quantize_cols(w, 4)
    want = ref.dequant_matmul_ref(xq, wq, sx, zx, sw, zw)
    xq_p = jnp.zeros((M, K), jnp.int8).at[:, :K_true].set(xq)
    wq_p = jnp.zeros((K, N), jnp.int8).at[:K_true].set(wq)
    y = quant_matmul(xq_p, ref.pack_int4(wq_p), sx, zx, sw, zw,
                     packed=True, k_true=K_true, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-3, atol=0.1)
    # without the correction the padded run is measurably off
    y_bad = quant_matmul(xq_p, ref.pack_int4(wq_p), sx, zx, sw, zw,
                         packed=True, interpret=True)
    assert float(jnp.max(jnp.abs(y_bad - want))) > 1.0


def test_unpack_variants_agree():
    """The kernel-side, deploy-side and ref unpackers share one nibble
    layout (low nibble = even row) — all three invert ``pack_int4``."""
    from repro.core.deploy import unpack_int4_weight
    from repro.kernels.quant_matmul import unpack_int4
    w4 = jax.random.randint(jax.random.PRNGKey(7), (64, 32), -8, 8) \
        .astype(jnp.int8)
    packed = ref.pack_int4(w4)
    for fn in (unpack_int4, unpack_int4_weight, ref.unpack_int4_ref):
        assert bool(jnp.all(fn(packed) == w4)), fn.__name__


# --------------------------- fake quant ------------------------------------

@pytest.mark.parametrize("shape", [(64, 32), (128, 100), (7, 257)])
@pytest.mark.parametrize("bits", [2, 4, 8, 32])
def test_fake_quant_kernel(shape, bits):
    x = jax.random.normal(jax.random.PRNGKey(bits), shape)
    a = ops.fused_fake_quant(x, bits)
    b = ref.fake_quant_ref(x, bits)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fake_quant_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 16)).astype(dtype)
    a = ops.fused_fake_quant(x, 8)
    assert a.dtype == dtype


# --------------------------- flash attention -------------------------------

@pytest.mark.parametrize("S,H,KV,D", [(128, 4, 4, 32), (200, 8, 2, 16),
                                      (512, 4, 1, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
def test_flash_attention(S, H, KV, D, causal, window):
    B = 2
    q = jax.random.normal(jax.random.PRNGKey(S), (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(S + 1), (B, KV, S, D))
    v = jax.random.normal(jax.random.PRNGKey(S + 2), (B, KV, S, D))
    a = ops.flash_attention(q, k, v, causal=causal, window=window)
    b = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 128, 32),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 32),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 32),
                          jnp.bfloat16)
    a = ops.flash_attention(q, k, v)
    b = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=0.04)


# --------------------------- rglru scan ------------------------------------

@pytest.mark.parametrize("B,S,C", [(2, 64, 96), (1, 128, 32), (3, 48, 256)])
def test_rglru_scan(B, S, C):
    a = jax.random.uniform(jax.random.PRNGKey(B), (B, S, C),
                           minval=0.4, maxval=0.99)
    b = jax.random.normal(jax.random.PRNGKey(S), (B, S, C))
    out = ops.rglru_scan(a, b)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_rglru_scan_initial_state():
    B, S, C = 2, 32, 64
    a = jax.random.uniform(jax.random.PRNGKey(0), (B, S, C), minval=0.5,
                           maxval=0.95)
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, C))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, C))
    out = ops.rglru_scan(a, b, h0)
    want = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# --------------------------- ssd scan --------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [(2, 64, 4, 16, 8, 16),
                                             (1, 128, 2, 32, 16, 32),
                                             (2, 96, 3, 8, 8, 32)])
def test_ssd_scan(B, S, H, P, N, chunk):
    xh = jax.random.normal(jax.random.PRNGKey(B), (B, S, H, P))
    dA = -jax.random.uniform(jax.random.PRNGKey(S), (B, S, H), maxval=0.5)
    Bm = jax.random.normal(jax.random.PRNGKey(H), (B, S, N))
    Cm = jax.random.normal(jax.random.PRNGKey(P), (B, S, N))
    y, fin = ops.ssd_scan(xh, dA, Bm, Cm, chunk=chunk)
    yr, fr = ref.ssd_scan_ref(xh, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fr), rtol=2e-4,
                               atol=2e-4)


def test_ssd_matches_model_path():
    """Kernel agrees with the chunked jnp path used inside mamba2 blocks."""
    from repro.models.blocks import ssd_chunked
    B, S, H, P, N = 1, 64, 2, 16, 8
    xh = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P))
    dA = -jax.random.uniform(jax.random.PRNGKey(1), (B, S, H), maxval=0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(2), (B, S, N))
    Cm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N))
    y_model, f_model = ssd_chunked(xh, dA, Bm, Cm, chunk=16)
    y_kern, f_kern = ops.ssd_scan(xh, dA, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kern),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f_model), np.asarray(f_kern),
                               rtol=2e-4, atol=2e-4)


# ----------------------- fused MLP3 + flat Polyak ---------------------------

def _mlp_params(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b)) / jnp.sqrt(a),
             "b": jax.random.normal(jax.random.fold_in(k, 1), (b,)) * 0.1}
            for k, (a, b) in zip(ks, zip(dims[:-1], dims[1:]))]


def _mlp_ref(params, x, sigmoid):
    h = x
    for i, l in enumerate(params):
        h = h @ l["w"] + l["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return jax.nn.sigmoid(h) if sigmoid else h


@pytest.mark.parametrize("B,dims,final", [
    (16, (10, 400, 300, 6), "sigmoid"),     # paper actor trunk
    (16, (16, 400, 300, 1), "linear"),      # paper critic trunk
    (33, (7, 50, 30, 5), "sigmoid"),        # odd dims exercise padding
    (8, (128, 128, 128, 128), "linear"),    # exactly lane-aligned
])
def test_fused_mlp3_forward_matches_ref(B, dims, final):
    params = _mlp_params(jax.random.PRNGKey(B), dims)
    x = jax.random.normal(jax.random.PRNGKey(B + 1), (B, dims[0]))
    y = ops.fused_mlp3(params, x, final=final)
    yr = _mlp_ref(params, x, final == "sigmoid")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("final", ["linear", "sigmoid"])
def test_fused_mlp3_backward_matches_ref(final):
    dims = (9, 40, 30, 3)
    params = _mlp_params(jax.random.PRNGKey(7), dims)
    x = jax.random.normal(jax.random.PRNGKey(8), (24, dims[0]))

    def loss_k(p, x):
        return jnp.sum(ops.fused_mlp3(p, x, final=final) ** 2)

    def loss_r(p, x):
        return jnp.sum(_mlp_ref(p, x, final == "sigmoid") ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1))(params, x)
    gr = jax.grad(loss_r, argnums=(0, 1))(params, x)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_fused_mlp3_under_jit_and_vmap():
    dims = (6, 32, 24, 4)
    params = _mlp_params(jax.random.PRNGKey(9), dims)
    x = jax.random.normal(jax.random.PRNGKey(10), (16, dims[0]))
    y = jax.jit(lambda p, x: ops.fused_mlp3(p, x, final="sigmoid"))(
        params, x)
    yr = _mlp_ref(params, x, True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sizes", [
    [(400, 300), (300,), (300, 1)],     # lane-unaligned leaves
    [(7,), (13, 5)],                    # total size not a lane multiple
    [(256, 128)],                       # exactly aligned
])
def test_fused_polyak_matches_tree_map(sizes):
    keys = jax.random.split(jax.random.PRNGKey(11), 2 * len(sizes))
    target = [jax.random.normal(keys[2 * i], s)
              for i, s in enumerate(sizes)]
    online = [jax.random.normal(keys[2 * i + 1], s)
              for i, s in enumerate(sizes)]
    tau = 0.01
    out = ops.fused_polyak(target, online, tau)
    ref_out = jax.tree.map(lambda t, p: (1 - tau) * t + tau * p,
                           target, online)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref_out)):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_fused_polyak_nested_tree():
    """Dict-of-list params (the ddpg layout) survive the flatten trip."""
    target = [{"w": jnp.ones((5, 3)), "b": jnp.zeros((3,))},
              {"w": jnp.full((3, 2), 2.0), "b": jnp.ones((2,))}]
    online = jax.tree.map(lambda x: x + 1.0, target)
    out = ops.fused_polyak(target, online, 0.5)
    ref_out = jax.tree.map(lambda t, p: 0.5 * t + 0.5 * p, target, online)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref_out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
