"""Functional agent core: scalar-update parity, fused-chunk parity,
vmapped population parity, and DeviceReplay/ReplayBuffer equivalence.

The contract proved here (mirrors PR 1's rollout-parity suite):

  * ``update_step`` == the legacy ``DDPGAgent.update`` host path given
    the same sampled batch (losses and resulting params within 1e-5);
  * ``update_chunk`` == n sequential legacy updates when the legacy
    path is fed exactly the batches the chunk's in-scan sampler draws;
  * ``jit(vmap(update_chunk))`` over a stacked population == P
    independent single-agent chunks;
  * ``DeviceReplay`` ring semantics == host ``ReplayBuffer`` (the
    reference), including wraparound and oversized batches, and both
    sample deterministically under a fixed seed.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.ddpg import (AgentState, DDPGAgent, DDPGConfig, agent_act,
                             agent_init, chunk_sample_keys,
                             population_update_chunk, tree_index, tree_stack,
                             update_chunk, update_step)
from repro.core.replay import (DeviceReplay, ReplayBuffer,
                               device_replay_sample)
from repro.core.search import SearchConfig

CFG = DDPGConfig(state_dim=6, action_dim=2, hidden=(16, 16), batch_size=8,
                 buffer_size=64, warmup_episodes=0, updates_per_episode=4)


def _fill(rng, *replays, n=40, state_dim=6, action_dim=2):
    """Push the same n random transitions into every buffer given."""
    for i in range(n):
        s = rng.random(state_dim).astype(np.float32)
        a = rng.random(action_dim).astype(np.float32)
        r = float(rng.standard_normal())
        s2 = rng.random(state_dim).astype(np.float32)
        d = float(i % 10 == 9)
        for rep in replays:
            rep.push(s, a, r, s2, d)


class _ScriptedReplay:
    """Host replay stub that replays a fixed sequence of batches — lets
    the legacy ``DDPGAgent.update`` consume exactly the batches an
    ``update_chunk`` scan drew."""

    def __init__(self, batches):
        self.batches = list(batches)
        self.i = 0

    def sample(self, batch_size):
        b = self.batches[self.i]
        self.i += 1
        return b

    def __len__(self):
        return 10 ** 9


def _params_close(a, b, atol):
    ja, jb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(ja) == len(jb)
    for x, y in zip(ja, jb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


# ------------------------------------------------------ scalar parity

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_update_step_matches_legacy_update(seed):
    """One ``update_step`` == one legacy ``DDPGAgent.update`` on the
    same sampled batch: same losses, same resulting parameters."""
    rng = np.random.default_rng(seed)
    legacy = DDPGAgent(CFG, seed=int(seed % 1000))
    legacy.observe_states(rng.standard_normal((32, 6)).astype(np.float32))
    batch = (rng.random((8, 6)).astype(np.float32),
             rng.random((8, 2)).astype(np.float32),
             rng.standard_normal(8).astype(np.float32),
             rng.random((8, 6)).astype(np.float32),
             (rng.random(8) > 0.8).astype(np.float32))
    st0 = legacy.state_for_dispatch()
    lc0, la0 = legacy.update(_ScriptedReplay([batch]))

    st1, (lc1, la1) = jax.jit(update_step, static_argnums=0)(
        CFG, st0, tuple(jnp.asarray(x) for x in batch))
    assert float(lc1) == pytest.approx(lc0, abs=1e-5)
    assert float(la1) == pytest.approx(la0, abs=1e-5)
    _params_close(st1.actor, legacy.actor, 1e-5)
    _params_close(st1.critic, legacy.critic, 1e-5)
    _params_close(st1.target_actor, legacy.target_actor, 1e-5)
    _params_close(st1.target_critic, legacy.target_critic, 1e-5)
    assert float(st1.reward_ma) == pytest.approx(legacy.reward_ma, abs=1e-5)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_update_chunk_matches_sequential_legacy(seed):
    """A fused n-step chunk (in-scan sampling included) == n sequential
    legacy updates fed the exact batches the chunk draws."""
    n = 4
    rng = np.random.default_rng(seed)
    chunky = DDPGAgent(CFG, seed=int(seed % 1000))
    legacy = DDPGAgent(CFG, seed=int(seed % 1000))
    dev = DeviceReplay(CFG.buffer_size, 6, 2, seed=0)
    _fill(rng, dev)
    obs = rng.standard_normal((32, 6)).astype(np.float32)
    chunky.observe_states(obs)
    legacy.observe_states(obs)

    # replay the chunk's PRNG stream to extract the batches it will draw
    _, keys = chunk_sample_keys(chunky.state.key, n)
    batches = [
        tuple(np.asarray(x)
              for x in device_replay_sample(dev.data, k, CFG.batch_size))
        for k in keys]

    lcs, las = chunky.update_chunk(dev, n)
    scripted = _ScriptedReplay(batches)
    ref = np.asarray([legacy.update(scripted) for _ in range(n)])
    np.testing.assert_allclose(lcs, ref[:, 0], atol=1e-5)
    np.testing.assert_allclose(las, ref[:, 1], atol=1e-5)
    _params_close(chunky.actor, legacy.actor, 1e-5)
    _params_close(chunky.critic, legacy.critic, 1e-5)
    _params_close(chunky.target_actor, legacy.target_actor, 1e-5)
    _params_close(chunky.target_critic, legacy.target_critic, 1e-5)
    assert chunky.reward_ma == pytest.approx(legacy.reward_ma, abs=1e-5)


def test_update_chunk_deterministic():
    """Same state + same replay -> same chunk results (and the carry
    key advances, so the next chunk draws a fresh stream)."""
    rng = np.random.default_rng(0)
    a1, a2 = DDPGAgent(CFG, seed=5), DDPGAgent(CFG, seed=5)
    d1 = DeviceReplay(CFG.buffer_size, 6, 2, seed=1)
    d2 = DeviceReplay(CFG.buffer_size, 6, 2, seed=1)
    _fill(rng, d1, d2)
    l1 = a1.update_chunk(d1, 3)
    l2 = a2.update_chunk(d2, 3)
    np.testing.assert_array_equal(l1[0], l2[0])
    l1b = a1.update_chunk(d1, 3)
    assert not np.array_equal(l1[0], l1b[0])


# --------------------------------------------------- population parity

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=3, deadline=None)
def test_population_chunk_matches_independent(seed):
    """jit(vmap(update_chunk)) over P stacked agents == P independent
    single-agent chunks (params and losses within 1e-5)."""
    P, n = 3, 3
    rng = np.random.default_rng(seed)
    agents, devs = [], []
    for p in range(P):
        ag = DDPGAgent(CFG, seed=int(seed % 1000) + p)
        dv = DeviceReplay(CFG.buffer_size, 6, 2, seed=p)
        _fill(rng, dv)           # different transitions per member
        ag.observe_states(rng.standard_normal((16, 6)).astype(np.float32))
        agents.append(ag)
        devs.append(dv)

    states = tree_stack([ag.state_for_dispatch() for ag in agents])
    datas = tree_stack([dv.data for dv in devs])
    pop_states, (pop_lc, _) = population_update_chunk(CFG, states, datas, n)

    for i, (ag, dv) in enumerate(zip(agents, devs)):
        lc, _la = ag.update_chunk(dv, n)       # independent fused chunk
        np.testing.assert_allclose(np.asarray(pop_lc)[i], lc, atol=1e-5)
        member = tree_index(pop_states, i)
        _params_close(member.actor, ag.actor, 1e-5)
        _params_close(member.critic, ag.critic, 1e-5)
        _params_close(member.target_actor, ag.target_actor, 1e-5)
        assert float(member.reward_ma) == pytest.approx(ag.reward_ma,
                                                        abs=1e-5)


# ------------------------------------------------- device replay parity

@pytest.mark.parametrize("capacity,chunks", [
    (64, (40,)),          # vectorized write, no wraparound
    (32, (20, 20, 20)),   # vectorized writes that wrap the ring
    (16, (40,)),          # oversized batch -> tail write
    (16, (7, 40, 9)),     # oversized batch mid-stream, nonzero ptr
])
def test_device_replay_matches_host(capacity, chunks):
    """DeviceReplay ring writes land exactly where the host reference
    puts them, for single pushes, bulk, wraparound and oversized."""
    rng = np.random.default_rng(5)
    sd, ad = 6, 2
    host = ReplayBuffer(capacity, sd, ad, seed=0)
    dev = DeviceReplay(capacity, sd, ad, seed=0)
    for n in chunks:
        s = rng.random((n, sd)).astype(np.float32)
        a = rng.random((n, ad)).astype(np.float32)
        r = rng.random(n).astype(np.float32)
        s2 = rng.random((n, sd)).astype(np.float32)
        d = (rng.random(n) > 0.5).astype(np.float32)
        host.push_batch(s, a, r, s2, d)
        dev.push_batch(s, a, r, s2, d)
    assert host.ptr == dev.ptr == int(dev.data.ptr)
    assert host.size == dev.size == int(dev.data.size) == len(dev)
    np.testing.assert_array_equal(host.states, np.asarray(dev.data.states))
    np.testing.assert_array_equal(host.actions, np.asarray(dev.data.actions))
    np.testing.assert_array_equal(host.rewards, np.asarray(dev.data.rewards))
    np.testing.assert_array_equal(host.next_states,
                                  np.asarray(dev.data.next_states))
    np.testing.assert_array_equal(host.dones, np.asarray(dev.data.dones))


@pytest.mark.parametrize("cls", [ReplayBuffer, DeviceReplay])
def test_replay_sample_deterministic_under_seed(cls):
    """Same seed + same transitions in -> same sample stream out, for
    both the host reference and the device buffer."""
    rng = np.random.default_rng(9)
    b1 = cls(32, 4, 1, seed=7)
    b2 = cls(32, 4, 1, seed=7)
    _fill(rng, b1, b2, n=48, state_dim=4, action_dim=1)
    for _ in range(3):
        s1 = b1.sample(8)
        s2 = b2.sample(8)
        for x, y in zip(s1, s2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and the stream advances: consecutive draws differ
    nxt = b1.sample(8)
    assert not all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(s1, nxt))


def test_replay_wraparound_oldest_evicted():
    for cls in (ReplayBuffer, DeviceReplay):
        buf = cls(4, 2, 1, seed=0)
        for i in range(6):
            buf.push(np.full(2, i, np.float32), np.asarray([i], np.float32),
                     float(i), np.full(2, i + 1, np.float32), i == 5)
        assert len(buf) == 4
        s, a, r, s2, d = buf.sample(16)
        assert set(np.unique(np.asarray(r))) <= {2.0, 3.0, 4.0, 5.0}


# ------------------------------------------------------- pure act / cfg

def test_agent_act_pure_matches_host_mean():
    """sigma=0: the pure jax act == the host numpy rollout forward."""
    agent = DDPGAgent(CFG, seed=3)
    rng = np.random.default_rng(0)
    agent.observe_states(rng.standard_normal((64, 6)).astype(np.float32))
    s = rng.standard_normal(6).astype(np.float32)
    host = agent.act(s, sigma=0.0)
    pure = np.asarray(agent_act(CFG, agent.state_for_dispatch(),
                                jnp.asarray(s), jax.random.PRNGKey(0), 0.0))
    np.testing.assert_allclose(pure, host, atol=1e-5)


def test_agent_act_pure_bounded():
    agent = DDPGAgent(CFG, seed=3)
    s = np.random.default_rng(1).standard_normal(6).astype(np.float32)
    for i, sigma in enumerate((0.1, 0.5, 2.0)):
        a = np.asarray(agent_act(CFG, agent.state, jnp.asarray(s),
                                 jax.random.PRNGKey(i), sigma))
        assert a.shape == (2,)
        assert np.all((a >= 0) & (a <= 1))


def test_agent_state_is_pytree():
    st = agent_init(CFG, jax.random.PRNGKey(0))
    leaves = jax.tree.leaves(st)
    assert all(hasattr(x, "dtype") for x in leaves)
    stacked = tree_stack([st, st])
    assert stacked.norm_mean.shape == (2, CFG.state_dim)
    back = tree_index(stacked, 1)
    np.testing.assert_array_equal(np.asarray(back.norm_mean),
                                  np.asarray(st.norm_mean))


def test_search_config_reward_default_not_shared():
    """Regression: the RewardConfig default must not be a shared
    mutable instance across SearchConfig objects."""
    a, b = SearchConfig(), SearchConfig()
    assert a.reward == b.reward
    assert a.reward is not b.reward
