"""Compression application tests: cspec structure invariance, mask counts,
deployment slicing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.compress import (CompressibleLM, lm_layer_specs,
                                 slice_lm_params)
from repro.core.policy import Policy
from repro.core.spec import LayerCMP
from repro.models import model as M


def test_cspec_structure_invariant(tiny_lm):
    cm, _ = tiny_lm
    ref = Policy.reference(cm.specs)
    agg = Policy([LayerCMP(keep=max(1, s.prune_dim // 2) if s.prune_dim
                           else 0, mode="INT8", w_bits=8, a_bits=8)
                  for s in cm.specs])
    c1 = cm.build_cspec(ref)
    c2 = cm.build_cspec(agg)
    assert (jax.tree_util.tree_structure(c1)
            == jax.tree_util.tree_structure(c2))
    # same SHAPES too -> single jit compilation serves the search
    s1 = jax.tree.map(lambda x: x.shape, c1)
    s2 = jax.tree.map(lambda x: x.shape, c2)
    assert s1 == s2


def test_mask_counts(tiny_lm):
    cm, _ = tiny_lm
    pol = Policy.reference(cm.specs)
    for i, s in enumerate(cm.specs):
        if s.kind == "mlp_up":
            pol.cmps[i] = LayerCMP(keep=128)
    cs = cm.build_cspec(pol)
    ffm = cs["blocks"]["mlp"]["ff_mask"]     # [L, ff]
    counts = np.asarray(jnp.sum(ffm, axis=-1))
    assert (counts == 128).all()


def test_compression_changes_outputs(tiny_lm):
    cm, batch = tiny_lm
    ref = cm.build_cspec(Policy.reference(cm.specs))
    hard = cm.build_cspec(Policy([
        LayerCMP(keep=max(1, s.prune_dim // 4) if s.prune_dim else 0,
                 mode="MIX", w_bits=2, a_bits=2) for s in cm.specs]))
    lo_ref = cm.logits(batch, ref)
    lo_hard = cm.logits(batch, hard)
    assert float(jnp.mean(jnp.abs(lo_ref - lo_hard))) > 1e-3


def test_reference_cspec_is_identity(tiny_lm):
    cm, batch = tiny_lm
    plain = cm.logits(batch, None)
    ref = cm.logits(batch, cm.build_cspec(Policy.reference(cm.specs)))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_slice_lm_params_shapes():
    cfg = ArchConfig(name="u", num_layers=2, d_model=64, num_heads=4,
                     num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=64,
                     scan_layers=False)
    params = M.init(cfg, jax.random.PRNGKey(0))
    cm = CompressibleLM(cfg, params)
    pol = Policy.reference(cm.specs)
    for i, s in enumerate(cm.specs):
        if s.kind == "mlp_up":
            pol.cmps[i] = LayerCMP(keep=128)
    cs = cm.build_cspec(pol)
    sliced = slice_lm_params(cfg, params, cs)
    for blk in sliced["blocks"]:
        assert blk["mlp"]["w_up"]["w"].shape == (64, 128)
        assert blk["mlp"]["w_down"]["w"].shape == (128, 64)
    # sliced model still runs
    toks = jnp.zeros((1, 8), jnp.int32)
    cfg_r = cfg.replace(d_ff=128)
    out = M.forward(cfg_r, sliced, tokens=toks)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_specs_cover_all_layer_kinds():
    for name, kw in [
        ("moe", dict(moe__num_experts=4)),
    ]:
        pass
    cfg = ArchConfig(name="m", num_layers=2, d_model=64, num_heads=4,
                     num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64)
    kinds = {s.kind for s in lm_layer_specs(cfg)}
    assert {"embed", "attn_qkv", "attn_out", "mlp_up", "mlp_down",
            "head"} <= kinds
