"""FleetSearch: mesh-sharded population epochs + preemption-safe resume.

Three tiers:
* in-process 1-device tests (mesh construction errors, fleet invariants);
* in-process mesh tests gated by ``conftest.require_devices`` — skipped
  in the ordinary suite, exercised by CI's dedicated multi-device step
  (a fresh pytest process under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
* subprocess tests that run the full acceptance scenario on an 8-device
  forced-host CPU mesh: sharded-vs-single-device records parity <=1e-5,
  the shared-dispatch probe, kill-at-epoch-N -> restore -> bit-for-bit
  resume, and the 4->2-device elastic restore.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from conftest import require_devices

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# 1-device tests
# ---------------------------------------------------------------------------

def test_make_dev_mesh_clear_error():
    from repro.launch.mesh import make_dev_mesh
    have = len(jax.devices())
    with pytest.raises(ValueError) as e:
        make_dev_mesh(data=have + 1, model=2)
    msg = str(e.value)
    assert str(2 * (have + 1)) in msg          # names the required count
    assert "xla_force_host_platform_device_count" in msg


def test_require_devices_helper_skips():
    with pytest.raises(pytest.skip.Exception) as e:
        require_devices(len(jax.devices()) + 1)
    assert "xla_force_host_platform_device_count" in str(e.value)


def _fleet_members(tiny_lm, n=2, epoch_batches=2):
    from repro.core.ddpg import DDPGConfig
    from repro.core.latency import LatencyContext
    from repro.core.reward import RewardConfig
    from repro.core.search import FusedCompressionSearch, SearchConfig
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    members, sens = [], None
    for p in range(n):
        scfg = SearchConfig(
            methods="pq", episodes=32,
            reward=RewardConfig(target_ratio=0.5),
            ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                            batch_size=16, buffer_size=256),
            seed=p)
        m = FusedCompressionSearch(cm, batch, scfg, ctx, sens=sens,
                                   batch_size=4,
                                   epoch_batches=epoch_batches)
        sens = m.sens
        members.append(m)
    return members


def test_fleet_rejects_non_epoch_members(tiny_lm):
    from repro.core.search import FleetSearch
    members = _fleet_members(tiny_lm, n=2, epoch_batches=0)
    with pytest.raises(ValueError, match="epoch mode"):
        FleetSearch(members)


def test_fleet_rejects_mesh_without_data_axis(tiny_lm):
    from repro.core.search import FleetSearch
    mesh = jax.make_mesh((1,), ("model",))
    members = _fleet_members(tiny_lm, n=2)
    with pytest.raises(ValueError, match="data"):
        FleetSearch(members, mesh=mesh)


def test_fleet_checkpoint_requires_dir(tiny_lm):
    from repro.core.search import FleetSearch
    fleet = FleetSearch(_fleet_members(tiny_lm, n=2))
    with pytest.raises(ValueError, match="ckpt_dir"):
        fleet.save_checkpoint()
    with pytest.raises(ValueError, match="directory"):
        fleet.restore_latest_checkpoint()


def test_fleet_episodes_must_be_whole_batches(tiny_lm):
    from repro.core.search import FleetSearch
    fleet = FleetSearch(_fleet_members(tiny_lm, n=2))
    with pytest.raises(ValueError, match="multiple"):
        fleet.run_fleet(6)          # batch size is 4


# ---------------------------------------------------------------------------
# mesh-gated in-process tests (run in CI's multi-device step)
# ---------------------------------------------------------------------------

def test_population_shardings_member_axis():
    require_devices(4)
    import jax.numpy as jnp
    import numpy as np
    from repro.core.ddpg import tree_stack
    from repro.distributed.sharding import (member_sharding, pad_members,
                                            population_shardings)
    from repro.launch.mesh import make_dev_mesh
    mesh = make_dev_mesh(data=4, model=1)
    trees = [{"w": jnp.full((3, 2), i, jnp.float32),
              "s": jnp.float32(i)} for i in range(3)]
    padded = pad_members(trees, mesh.shape["data"])
    assert len(padded) == 4 and padded[-1] is trees[-1]
    stacked = tree_stack(padded,
                         shardings=None)
    sh = population_shardings(stacked, mesh)
    placed = jax.device_put(stacked, sh)
    # member axis really spans the data axis, one member per device
    assert len(placed["w"].sharding.device_set) == 4
    assert placed["w"].sharding.spec[0] == "data"
    assert placed["s"].shape == (4,)
    assert len(placed["s"].sharding.device_set) == 4
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.asarray(stacked["w"]))
    # 0-d leaves replicate (no member axis to split)
    assert member_sharding(mesh, 0).spec == jax.sharding.PartitionSpec()


def test_tree_stack_places_on_mesh():
    require_devices(2)
    import jax.numpy as jnp
    from repro.core.ddpg import tree_stack
    from repro.distributed.sharding import population_shardings
    from repro.launch.mesh import make_dev_mesh
    mesh = make_dev_mesh(data=2, model=1)
    trees = [{"w": jnp.ones((4, 4)) * i} for i in range(2)]
    stacked = tree_stack(trees)
    placed = tree_stack(trees,
                        shardings=population_shardings(stacked, mesh))
    assert len(placed["w"].sharding.device_set) == 2


# ---------------------------------------------------------------------------
# subprocess acceptance tests (8 forced host devices)
# ---------------------------------------------------------------------------

_PARITY_RESUME = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import tempfile
    import jax
    from benchmarks.search_setup import \\
        assert_population_epoch_dispatch_count
    from repro.launch.fleet import tiny_fleet

    d = tempfile.mkdtemp()
    out = {"devices": len(jax.devices())}

    def recs(results):
        return [[(r.episode, r.reward, r.accuracy, r.latency_s)
                 for r in res.history] for res in results]

    # sharded P=4 fleet on a 4-device mesh, checkpointing every epoch
    fa = tiny_fleet(members=4, data=4, seed0=0, ckpt_dir=d, ckpt_every=1)
    head = recs(fa.run_fleet(16))        # epochs 1-2 (checkpointed)
    fa._ckpt.wait()
    fa._ckpt = None                      # LATEST stays at epoch 2
    tail = recs(fa.run_fleet(24))        # epoch 3 (post-"kill" reference)
    out["mesh"] = dict(fa.mesh.shape)

    # dispatch probe: a steady-state epoch is ONE shared sharded dispatch
    probe = assert_population_epoch_dispatch_count(fa, fa.epoch_cursor, 2)
    out["pop_epoch"] = probe["pop_epoch"]

    # parity: the same fleet pinned to one device (no mesh)
    fs = tiny_fleet(members=4, data=0, seed0=0)
    solo = recs(fs.run_fleet(24))
    md = 0.0
    for ml, sl in zip([h + t for h, t in zip(head, tail)], solo):
        assert len(ml) == len(sl)
        for p, q in zip(ml, sl):
            assert p[0] == q[0]
            md = max(md, abs(p[1] - q[1]), abs(p[2] - q[2]),
                     abs(p[3] - q[3]) / max(1e-30, abs(q[3])))
    out["parity_maxdiff"] = md

    # kill-at-epoch-2 -> restore_latest -> resume, bit-for-bit
    fr = tiny_fleet(members=4, data=4, seed0=0, ckpt_dir=d)
    extra = fr.restore_latest_checkpoint()
    out["resume_cursor"] = extra["epoch_cursor"]
    out["manifest_mesh"] = extra["mesh_shape"]
    out["manifest_seeds"] = extra["member_seeds"]
    out["manifest_ring_size"] = extra["ring_size"]
    out["resume_bit_exact"] = recs(fr.run_fleet(24)) == tail
    print(json.dumps(out))
""")

_ELASTIC_RESUME = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import tempfile
    import jax
    from repro.distributed.fault_tolerance import elastic_data_axis
    from repro.launch.fleet import tiny_fleet
    from repro.launch.mesh import make_dev_mesh

    d = tempfile.mkdtemp()

    def recs(results):
        return [[(r.episode, r.reward, r.accuracy, r.latency_s)
                 for r in res.history] for res in results]

    # save at epoch 2 on a 4-device mesh, keep running uninterrupted
    fa = tiny_fleet(members=4, data=4, seed0=0, ckpt_dir=d, ckpt_every=1)
    fa.run_fleet(16)
    fa._ckpt.wait()
    fa._ckpt = None
    ref = recs(fa.run_fleet(24))         # epoch 3, uninterrupted

    # restart after losing half the devices: elastic_data_axis picks the
    # data extent 2 survivors support; restore re-shards onto that mesh
    data = elastic_data_axis(1, 2, 1)
    fb = tiny_fleet(members=4, seed0=0, ckpt_dir=d,
                    mesh=make_dev_mesh(data, 1))
    extra = fb.restore_latest_checkpoint()
    got = recs(fb.run_fleet(24))
    md = 0.0
    for ml, sl in zip(ref, got):
        assert len(ml) == len(sl)
        for p, q in zip(ml, sl):
            assert p[0] == q[0]
            md = max(md, abs(p[1] - q[1]), abs(p[2] - q[2]),
                     abs(p[3] - q[3]) / max(1e-30, abs(q[3])))
    print(json.dumps({"elastic_data": data, "maxdiff": md,
                      "resume_cursor": extra["epoch_cursor"],
                      "saved_mesh": extra["mesh_shape"]}))
""")


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep \
        + os.path.abspath(ROOT)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_fleet_subprocess_parity_probe_resume():
    """ISSUE 8 acceptance: on an 8-device forced-host CPU mesh a P=4
    population epoch runs as sharded dispatches with records parity
    <=1e-5 vs the single-device path, the dispatch-count probe holds,
    and kill-at-epoch-N -> restore_latest -> resume reproduces the
    uninterrupted run's records bit-for-bit."""
    out = _run_subprocess(_PARITY_RESUME)
    assert out["devices"] == 8
    assert out["mesh"] == {"data": 4, "model": 1}
    assert out["pop_epoch"] == 1
    assert out["parity_maxdiff"] <= 1e-5, out
    assert out["resume_cursor"] == 16
    assert out["manifest_mesh"] == {"data": 4, "model": 1}
    assert out["manifest_seeds"] == [0, 1, 2, 3]
    assert all(s > 0 for s in out["manifest_ring_size"])
    assert out["resume_bit_exact"] is True, out


@pytest.mark.slow
def test_fleet_subprocess_elastic_resume():
    """Satellite: save at epoch N on a 4-device mesh, restore onto 2
    devices via ``elastic_data_axis``, epoch N+1 records parity <=1e-5
    vs the uninterrupted run."""
    out = _run_subprocess(_ELASTIC_RESUME)
    assert out["elastic_data"] == 2
    assert out["saved_mesh"] == {"data": 4, "model": 1}
    assert out["resume_cursor"] == 16
    assert out["maxdiff"] <= 1e-5, out
