"""Sensitivity analysis (Eq. 5) tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import Policy
from repro.core.sensitivity import (SensitivityResult, kl_divergence,
                                    run_sensitivity)


def test_kl_nonnegative_and_zero_on_self():
    lp = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    assert float(kl_divergence(lp, lp)) == pytest.approx(0.0, abs=1e-7)
    lq = jnp.log(jnp.asarray([[0.5, 0.3, 0.2]]))
    assert float(kl_divergence(lp, lq)) > 0


def test_run_sensitivity_structure(tiny_lm):
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    assert set(sens.table.keys()) == {s.name for s in cm.specs}
    # every quantizable layer has w/a probes, every prunable has p probes
    for s in cm.specs:
        row = sens.table[s.name]
        if s.quantizable:
            assert "w2" in row and "a2" in row
            assert row["w2"] >= 0
        if s.prunable and s.prune_dim:
            assert "p50" in row and "p25" in row


def test_lower_bits_more_sensitive(tiny_lm):
    """On average across layers, 2-bit probes distort more than 4-bit."""
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    w2 = [r["w2"] for r in sens.table.values() if "w2" in r]
    w4 = [r["w4"] for r in sens.table.values() if "w4" in r]
    assert np.mean(w2) > np.mean(w4)


def test_more_pruning_more_sensitive(tiny_lm):
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    p50 = [r["p50"] for r in sens.table.values() if "p50" in r]
    p25 = [r["p25"] for r in sens.table.values() if "p25" in r]
    assert np.mean(p25) >= np.mean(p50)


def test_features_fixed_length(tiny_lm):
    cm, _ = tiny_lm
    sens = SensitivityResult({s.name: {} for s in cm.specs})
    for s in cm.specs:
        assert len(sens.features_for(s.name)) == 6
