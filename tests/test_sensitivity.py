"""Sensitivity analysis (Eq. 5) tests: fused-vs-sequential parity,
probe legality, dispatch-count bound, and the legality-aware feature
sentinel."""
import copy

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constraints import legalize, mix_allowed, round_keep
from repro.core.policy import Policy, PolicyBatch, policies_from_batch
from repro.core.sensitivity import (FEATURE_PROBES, MISSING_KL,
                                    SensitivityResult, build_probe_plan,
                                    feature_probe_plan, full_sweep,
                                    kl_divergence, run_sensitivity,
                                    run_sensitivity_sequential)
from repro.core.spec import effective_bits


def test_kl_nonnegative_and_zero_on_self():
    lp = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    assert float(kl_divergence(lp, lp)) == pytest.approx(0.0, abs=1e-7)
    lq = jnp.log(jnp.asarray([[0.5, 0.3, 0.2]]))
    assert float(kl_divergence(lp, lq)) > 0


def test_run_sensitivity_structure(tiny_lm):
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    assert set(sens.table.keys()) == {s.name for s in cm.specs}
    # every quantizable layer has w/a probes, every prunable has p probes
    for s in cm.specs:
        row = sens.table[s.name]
        if s.quantizable:
            assert "w2" in row and "a2" in row
            assert row["w2"] >= 0
        if s.prunable and s.prune_dim:
            assert "p50" in row and "p25" in row


def test_lower_bits_more_sensitive(tiny_lm):
    """On average across layers, 2-bit probes distort more than 4-bit."""
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    w2 = [r["w2"] for r in sens.table.values() if "w2" in r]
    w4 = [r["w4"] for r in sens.table.values() if "w4" in r]
    assert np.mean(w2) > np.mean(w4)


def test_more_pruning_more_sensitive(tiny_lm):
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    p50 = [r["p50"] for r in sens.table.values() if "p50" in r]
    p25 = [r["p25"] for r in sens.table.values() if "p25" in r]
    assert np.mean(p25) >= np.mean(p50)


def test_features_fixed_length(tiny_lm):
    cm, _ = tiny_lm
    sens = SensitivityResult({s.name: {} for s in cm.specs})
    for s in cm.specs:
        assert len(sens.features_for(s.name)) == 6


# ===========================================================================
# Fused core: parity, dispatch bound, memoization
# ===========================================================================

def _assert_table_parity(fused, seq, tol=1e-6):
    assert set(fused.table) == set(seq.table)
    for name, row in fused.table.items():
        assert set(row) == set(seq.table[name]), name
        for k, v in row.items():
            assert abs(v - seq.table[name][k]) <= tol, \
                (name, k, v, seq.table[name][k])


def test_fused_matches_sequential_lm(tiny_lm):
    """ISSUE 5 acceptance: per layer×probe KL parity <= 1e-6 between
    the one-dispatch fused core and the per-probe host-builder path."""
    cm, batch = tiny_lm
    _assert_table_parity(run_sensitivity(cm, batch, memo=False),
                         run_sensitivity_sequential(cm, batch))


def test_fused_matches_sequential_resnet(tiny_resnet):
    cm, batch = tiny_resnet
    _assert_table_parity(run_sensitivity(cm, batch, memo=False),
                         run_sensitivity_sequential(cm, batch))


@pytest.mark.parametrize("chunk", [1, 3, 8, 1024])
def test_fused_chunking_invariant(tiny_lm, chunk):
    """The scan-chunk size bounds memory, never the numbers (padding
    rows are reference policies and are dropped on the host)."""
    cm, batch = tiny_lm
    base = run_sensitivity(cm, batch, memo=False)
    _assert_table_parity(run_sensitivity(cm, batch, chunk=chunk,
                                         memo=False), base, tol=0.0)


def test_sensitivity_dispatch_count(tiny_lm):
    """One analysis = ONE fused jit execution, zero per-probe
    dispatches (the sensitivity analogue of the epoch dispatch bound)."""
    from benchmarks.search_setup import assert_sensitivity_dispatch_count
    cm, batch = tiny_lm
    counts = assert_sensitivity_dispatch_count(cm, batch)
    assert counts == {"fused": 1, "seq_probes": 0}


def test_memoized_across_constructors(tiny_lm):
    """Engines built on a common model+batch share one analysis (the
    PopulationSearch construction path)."""
    cm, batch = tiny_lm
    assert run_sensitivity(cm, batch) is run_sensitivity(cm, batch)
    assert run_sensitivity(cm, batch, memo=False) is not \
        run_sensitivity(cm, batch)


def test_full_sweep_is_fused_view(tiny_lm):
    """full_sweep rides the same fused core: rows match a sequential
    per-probe evaluation of the same (legalized) dense plan."""
    from repro.core.sensitivity import _plan_kls_sequential
    cm, batch = tiny_lm
    rows = full_sweep(cm, batch, w_bits=(4, 2), a_bits=(2,), n_prune=3)
    plan = build_probe_plan(cm.specs, w_probes=(4, 2), a_probes=(2,),
                            prune_fracs=tuple(np.linspace(0.1, 1.0, 3)))
    assert len(rows) == len(plan)
    seq = _plan_kls_sequential(cm, batch, plan)
    for r, e, kl in zip(rows, plan.entries, seq):
        assert (r["layer"], r["method"], r["param"]) == \
            (e.layer, e.method, e.param)
        assert abs(r["kl"] - kl) <= 1e-6


# ===========================================================================
# Probe legality (the bugfix satellites)
# ===========================================================================

def _plan_policies(specs, plan):
    return policies_from_batch(specs, PolicyBatch(
        keep=plan.keep, w_bits=plan.w_bits, a_bits=plan.a_bits))


@pytest.mark.parametrize("fixture", ["tiny_lm", "tiny_resnet"])
def test_probes_are_legalize_fixed_points(fixture, request):
    """Every probe row must be a reachable policy: re-applying
    ``legalize`` to any probed CMP changes nothing."""
    cm, _ = request.getfixturevalue(fixture)
    plan = feature_probe_plan(cm.specs)
    for pol, entry in zip(_plan_policies(cm.specs, plan), plan.entries):
        cmp = pol.cmps[entry.spec_idx]
        lc = legalize(cm.specs[entry.spec_idx], copy.deepcopy(cmp))
        assert (lc.keep, effective_bits(lc)) == \
            (cmp.keep, effective_bits(cmp)), (entry, cmp, lc)


def test_prune_probes_respect_granularity(tiny_lm):
    """Probed keep counts are ``round_keep`` outputs — granularity-
    aligned, floored at one granule, capped at the prunable dim (no
    more sub-granule keeps like ``int(prune_dim * frac)`` produced)."""
    cm, _ = tiny_lm
    plan = feature_probe_plan(cm.specs)
    seen = 0
    for p, e in enumerate(plan.entries):
        if e.method != "prune":
            continue
        s = cm.specs[e.spec_idx]
        keep = int(plan.keep[p, e.spec_idx])
        assert keep == round_keep(s, max(1, int(s.prune_dim * e.param)))
        g = max(1, s.prune_granularity)
        assert keep == s.prune_dim or keep % g == 0
        assert keep >= min(g, s.prune_dim)
        seen += 1
    assert seen > 0


def test_quant_probes_int8_fallback(tiny_lm):
    """MIX bit asks on mix_allowed-False layers probe the INT8 fallback
    (the paper's TVM/ARM rule), not an illegal sub-8-bit policy."""
    cm, _ = tiny_lm
    plan = feature_probe_plan(cm.specs)
    checked_fallback = checked_mix = 0
    for p, e in enumerate(plan.entries):
        if e.method not in ("quant_w", "quant_a"):
            continue
        s = cm.specs[e.spec_idx]
        w, a = plan.w_bits[p, e.spec_idx], plan.a_bits[p, e.spec_idx]
        if mix_allowed(s):
            want_w = e.param if e.method == "quant_w" else 32
            want_a = e.param if e.method == "quant_a" else 32
            assert (w, a) == (want_w, want_a), (e, w, a)
            checked_mix += 1
        else:
            assert (w, a) == (8, 8), (e, w, a)
            checked_fallback += 1
    assert checked_fallback > 0 and checked_mix > 0


def test_probe_rows_touch_single_layer(tiny_lm):
    """Each probe differs from the reference policy in exactly the
    probed column (or not at all, when legalization lands back on the
    reference — e.g. a prune probe rounded up to the full dim)."""
    cm, _ = tiny_lm
    plan = feature_probe_plan(cm.specs)
    ref_k, ref_w, ref_a = plan.ref
    for p, e in enumerate(plan.entries):
        for arr, ref in ((plan.keep, ref_k), (plan.w_bits, ref_w),
                         (plan.a_bits, ref_a)):
            diff = np.flatnonzero(arr[p] != ref)
            assert set(diff) <= {e.spec_idx}, (e, diff)


def test_feature_sentinel_distinguishes_unprobed():
    """Missing probes read MISSING_KL, not 0.0 — a non-quantizable
    layer no longer looks maximally robust to the agent."""
    sens = SensitivityResult({"q_only": {"w4": 0.0, "w2": 0.0, "a4": 0.0,
                                         "a2": 0.0},
                              "bare": {}})
    q = sens.features_for("q_only")
    assert q[:4] == [0.0] * 4                 # probed, insensitive
    assert q[4:] == [MISSING_KL] * 2          # not prunable
    assert sens.features_for("bare") == [MISSING_KL] * len(FEATURE_PROBES)
    rows = sens.feature_rows(["q_only", "bare"])
    assert rows.shape == (2, len(FEATURE_PROBES))
    np.testing.assert_array_equal(rows[1], MISSING_KL)


def test_feature_row_feeds_state(tiny_lm):
    """The state builder consumes the array-form feature row (sentinel
    included) for unprobed layers."""
    from repro.core.state import _compute_static_features
    cm, batch = tiny_lm
    sens = run_sensitivity(cm, batch)
    specs = cm.specs
    # head: quantizable but not prunable -> prune features are sentinel
    t = next(i for i, s in enumerate(specs) if s.name == "head")
    static, _, _, _ = _compute_static_features(
        specs, t, sens, _fake_ref_lat(specs))
    assert static[-1] == MISSING_KL and static[-2] == MISSING_KL
    np.testing.assert_allclose(static[-6:], sens.feature_row("head"),
                               rtol=1e-6)


def _fake_ref_lat(specs):
    class U:
        def __init__(self, name):
            self.name, self.time_s = name, 1.0

    class RL:
        units = [U(s.name) for s in specs]
        total_s = float(len(specs))

    return RL()
