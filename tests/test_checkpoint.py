"""Checkpoint: atomic save/restore, LATEST pointer, async, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointing as C


def tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.zeros((3,))},
            "opt": {"step": jnp.int32(7), "nested": [jnp.ones((2,))]}}


def test_roundtrip(tmp_path):
    t = tree()
    C.save(str(tmp_path), 10, t, extra={"data_step": 10})
    restored, extra = C.restore(str(tmp_path), 10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data_step"] == 10


def test_latest_pointer(tmp_path):
    t = tree()
    C.save(str(tmp_path), 5, t)
    C.save(str(tmp_path), 9, t)
    assert C.latest_step(str(tmp_path)) == 9
    restored, step, _ = C.restore_latest(str(tmp_path), t)
    assert step == 9


def test_gc_keeps_recent(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4, 5):
        C.save(str(tmp_path), s, t, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_4", "step_5"]


def test_async_checkpointer(tmp_path):
    ck = C.AsyncCheckpointer(str(tmp_path))
    t = tree()
    ck.save(3, t)
    ck.wait()
    restored, step, _ = C.restore_latest(str(tmp_path), t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_restore_missing_returns_none(tmp_path):
    out, step, extra = C.restore_latest(str(tmp_path), tree())
    assert out is None and step is None


def test_dangling_latest_falls_back_to_newest_intact(tmp_path):
    """A crash between step-dir GC and the pointer rewrite leaves LATEST
    naming a deleted step; restore must fall back to the newest intact
    manifest instead of raising."""
    import shutil
    t = tree()
    C.save(str(tmp_path), 5, t, extra={"mark": 5})
    C.save(str(tmp_path), 9, t, extra={"mark": 9})
    # simulate the crash: GC removed step_9's predecessor-pointer target
    with open(tmp_path / "LATEST", "w") as f:
        f.write("12")                     # names a step that never landed
    assert C.latest_step(str(tmp_path)) == 9
    restored, step, extra = C.restore_latest(str(tmp_path), t)
    assert step == 9 and extra["mark"] == 9
    # pointer names a GC'd dir
    shutil.rmtree(tmp_path / "step_9")
    with open(tmp_path / "LATEST", "w") as f:
        f.write("9")
    restored, step, extra = C.restore_latest(str(tmp_path), t)
    assert step == 5 and extra["mark"] == 5
    # unparsable pointer content
    with open(tmp_path / "LATEST", "w") as f:
        f.write("garbage")
    assert C.latest_step(str(tmp_path)) == 5
    # a step dir without a manifest (crash mid-rename) is never chosen
    os.makedirs(tmp_path / "step_7")
    assert C.latest_step(str(tmp_path)) == 5


def test_trainer_resume(tmp_path):
    """Trainer checkpoints and resumes at the right step (restart safety)."""
    from repro.configs.base import ArchConfig
    from repro.data.pipeline import bigram_lm
    from repro.optim.optimizer import OptimizerConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = ArchConfig(name="ck", num_layers=1, d_model=32, num_heads=2,
                     num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=6)
    tcfg = TrainerConfig(total_steps=6, ckpt_every=3, log_every=2,
                         ckpt_dir=str(tmp_path))
    tr = Trainer(cfg, ocfg, tcfg, seed=0)
    data = (bigram_lm(64, 4, 16, seed=i) for i in range(100))
    tr.fit(data)
    assert C.latest_step(str(tmp_path)) == 6

    tr2 = Trainer(cfg, ocfg, tcfg, seed=1)   # different init
    tr2.maybe_restore()
    assert tr2.step == 6
    a = jax.tree.leaves(tr.params)[0]
    b = jax.tree.leaves(tr2.params)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
