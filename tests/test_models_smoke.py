"""Per-assigned-architecture smoke tests (deliverable f): reduced config,
one forward + one train step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import model as M
from repro.models.registry import ARCH_IDS, get_config
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step


def _batch(cfg, B=2, S=32):
    out = {}
    if cfg.frontend == "audio_stub":
        out["embeds"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
        out["labels"] = jnp.zeros((B, S), jnp.int32)
    else:
        out["tokens"] = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                           cfg.vocab_size)
        if cfg.frontend == "vision_stub":
            out["embeds"] = jnp.ones((B, cfg.frontend_len, cfg.d_model),
                                     jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True).replace(param_dtype="float32",
                                               compute_dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    logits = M.forward(cfg, params, tokens=b.get("tokens"),
                       embeds=b.get("embeds"))
    B = 2
    S = 32
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True).replace(param_dtype="float32",
                                               compute_dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    b = _batch(cfg)
    params2, opt2, metrics = step(params, opt, b)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(a - c))),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b",
                                  "mamba2-780m", "recurrentgemma-2b"])
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, smoke=True).replace(param_dtype="float32",
                                               compute_dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(1))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    full = M.forward(cfg, params, tokens=toks)
    cache = M.init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(cfg, params, cache, toks[:, t:t + 1], t)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.max(jnp.abs(full - dec)) / (jnp.max(jnp.abs(full))
                                                + 1e-9))
    assert rel < 1e-4


def test_encoder_has_no_decode():
    from repro.configs.base import SHAPES_BY_NAME, cell_supported
    cfg = get_config("hubert-xlarge")
    ok, reason = cell_supported(cfg, SHAPES_BY_NAME["decode_32k"])
    assert not ok and "encoder" in reason


def test_long_context_skips():
    from repro.configs.base import SHAPES_BY_NAME, cell_supported
    long = SHAPES_BY_NAME["long_500k"]
    assert not cell_supported(get_config("olmo-1b"), long)[0]
    assert cell_supported(get_config("mamba2-780m"), long)[0]
    assert cell_supported(get_config("mixtral-8x22b"), long)[0]   # SWA
    assert cell_supported(get_config("recurrentgemma-2b"), long)[0]
