"""Batched episode engine: parity with the scalar path + properties.

Covers the four vectorized pieces (oracle, state builder, actor,
replay) and the assembled ``BatchedCompressionSearch``.
"""
import copy

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # seeded-random fallback shim
    from _propcheck import given, settings, st

from repro.configs.base import ArchConfig
from repro.core.compress import lm_layer_specs
from repro.core.ddpg import DDPGAgent, DDPGConfig
from repro.core.latency import (V5E, LatencyContext, policy_latency,
                                policy_latency_batch)
from repro.core.policy import Policy, map_actions, stack_policies
from repro.core.replay import ReplayBuffer
from repro.core.reward import RewardConfig
from repro.core.search import (BatchedCompressionSearch, CompressionSearch,
                               PopulationSearch, SearchConfig)
from repro.core.state import build_state, build_state_batch

CFG = ArchConfig(name="o", num_layers=4, d_model=256, num_heads=8,
                 num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=512)
SPECS = lm_layer_specs(CFG)
CTX = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)
CTXS = (CTX,
        LatencyContext(tokens=128, seq_ctx=512, mode="prefill", tp=4,
                       chips=4),
        LatencyContext(tokens=4, seq_ctx=0, mode="train"))


def rand_policy(rng) -> Policy:
    return Policy([map_actions(s, rng.random(3), "pq") for s in SPECS])


# ---------------------------------------------------------------- oracle

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_latency_batch_matches_scalar(seed):
    """policy_latency_batch == scalar policy_latency, all contexts."""
    rng = np.random.default_rng(seed)
    pols = [rand_policy(rng) for _ in range(6)]
    for ctx in CTXS:
        batched = policy_latency_batch(SPECS, pols, V5E, ctx).total_s
        scalar = np.asarray(
            [policy_latency(SPECS, p, V5E, ctx).total_s for p in pols])
        np.testing.assert_allclose(batched, scalar, rtol=1e-6, atol=1e-12)


def test_latency_batch_matches_scalar_resnet(tiny_resnet):
    cm, _ = tiny_resnet
    rng = np.random.default_rng(3)
    img_ctx = LatencyContext(tokens=1, seq_ctx=0, mode="prefill", batch=1)
    pols = [Policy([map_actions(s, rng.random(3), "pq") for s in cm.specs])
            for _ in range(5)]
    batched = policy_latency_batch(cm.specs, pols, V5E, img_ctx).total_s
    scalar = np.asarray(
        [policy_latency(cm.specs, p, V5E, img_ctx).total_s for p in pols])
    np.testing.assert_allclose(batched, scalar, rtol=1e-6, atol=1e-12)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_oracle_monotone_in_bits(seed):
    """Lowering effective bits (FP32 -> INT8 -> MIX4) never increases
    modeled latency."""
    rng = np.random.default_rng(seed)
    base = rand_policy(rng)
    ladder = (("FP32", 32, 32), ("INT8", 8, 8), ("MIX", 4, 4))
    prev = None
    for mode, wb, ab in ladder:
        pol = copy.deepcopy(base)
        for s, c in zip(SPECS, pol.cmps):
            if s.quantizable and (mode != "MIX" or s.mix_supported):
                c.mode, c.w_bits, c.a_bits = mode, wb, ab
        lat = policy_latency_batch(SPECS, [pol], V5E, CTX).total_s[0]
        if prev is not None:
            assert lat <= prev * (1 + 1e-12)
        prev = lat


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_oracle_monotone_in_keep(seed):
    """Lowering any unit's keep fraction never increases latency."""
    rng = np.random.default_rng(seed)
    pol = rand_policy(rng)
    lat0 = policy_latency_batch(SPECS, [pol], V5E, CTX).total_s[0]
    prunable = [i for i, s in enumerate(SPECS)
                if s.prunable and s.prune_dim]
    i = prunable[int(rng.integers(0, len(prunable)))]
    lower = copy.deepcopy(pol)
    lower.cmps[i].keep = max(1, lower.cmps[i].keep
                             - int(rng.integers(1, lower.cmps[i].keep + 1)))
    lat1 = policy_latency_batch(SPECS, [lower], V5E, CTX).total_s[0]
    assert lat1 <= lat0 * (1 + 1e-12)


def test_oracle_reference_matches_scalar_object():
    ref = Policy.reference(SPECS)
    b = policy_latency_batch(SPECS, [ref], V5E, CTX)
    s = policy_latency(SPECS, ref, V5E, CTX)
    assert b.total_s[0] == pytest.approx(s.total_s, rel=1e-9)
    assert b.unit_time_s.shape == (1, len(SPECS))
    # decided_before(L) + overhead == total
    assert b.decided_before(len(SPECS)) + b.overhead_s == pytest.approx(
        b.total_s[0], rel=1e-9)


# ------------------------------------------------------ accuracy / state

def _mk_search(tiny_lm, cls=CompressionSearch, **kw):
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods="pq", episodes=6, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=16, buffer_size=256))
    return cls(cm, batch, scfg, ctx, **kw)


def test_accuracy_batch_matches_scalar(tiny_lm):
    """vmap-of-jit accuracy over stacked cspecs == per-policy jit."""
    cm, batch = tiny_lm
    rng = np.random.default_rng(7)
    pols = [Policy([map_actions(s, rng.random(3), "pq") for s in cm.specs])
            for _ in range(3)]
    import jax
    jit_acc = jax.jit(lambda cs: cm.accuracy(batch, cs))
    scalar = np.asarray([float(jit_acc(cm.build_cspec(p))) for p in pols])
    stacked = np.asarray(
        cm.accuracy_batch(batch, cm.build_cspec_batch(pols)))
    fused = np.asarray(cm.accuracy_policy_batch(
        batch, stack_policies(cm.specs, pols)))
    np.testing.assert_allclose(stacked, scalar, atol=1e-6)
    np.testing.assert_allclose(fused, scalar, atol=1e-6)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "mamba2-780m",
                                  "recurrentgemma-2b", "arctic-480b"])
def test_accuracy_policy_batch_parity_archs(arch):
    """The traced cspec builder must mirror build_lm_cspec on every
    layer family — moe (incl. dense residual), ssm, rglru, attn."""
    import jax
    from repro.core.compress import CompressibleLM
    from repro.models import model as M
    from repro.models.registry import get_config

    cfg = get_config(arch, smoke=True).replace(param_dtype="float32",
                                               compute_dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    cm = CompressibleLM(cfg, params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    rng = np.random.default_rng(13)
    pols = [Policy([map_actions(s, rng.random(3), "pq") for s in cm.specs])
            for _ in range(2)]
    jit_acc = jax.jit(lambda cs: cm.accuracy(batch, cs))
    scalar = np.asarray([float(jit_acc(cm.build_cspec(p))) for p in pols])
    fused = np.asarray(cm.accuracy_policy_batch(
        batch, stack_policies(cm.specs, pols)))
    np.testing.assert_allclose(fused, scalar, atol=1e-6)


def test_build_state_batch_matches_scalar(tiny_lm):
    search = _mk_search(tiny_lm)
    rng = np.random.default_rng(11)
    K = 3
    partials = []
    for _ in range(K):
        p = copy.deepcopy(search.ref_policy)
        for i, s in enumerate(search.specs):
            p.cmps[i] = map_actions(s, rng.random(3), "pq")
        partials.append(p)
    prev_a = rng.random((K, 3)).astype(np.float32)
    for t in search.steps:
        cur = policy_latency_batch(
            search.specs, stack_policies(search.specs, partials),
            search.hw, search.ctx, search.cfg.window)
        got = build_state_batch(search.specs, t, cur, search.sens, prev_a,
                                search.ref_lat)
        for j in range(K):
            want = build_state(search.specs, t, partials[j], search.sens,
                               prev_a[j], search.hw, search.ctx,
                               search.ref_lat, search.cfg.window)
            np.testing.assert_allclose(got[j], want, atol=1e-6)


# ------------------------------------------------------- actor / replay

def test_act_batch_shapes_and_bounds():
    cfg = DDPGConfig(state_dim=8, action_dim=3)
    agent = DDPGAgent(cfg, seed=0)
    states = np.random.default_rng(0).random((5, 8)).astype(np.float32)
    a = agent.act_batch(states, np.full(5, 0.5), np.zeros(5, bool))
    assert a.shape == (5, 3) and a.dtype == np.float32
    assert np.all((a >= 0) & (a <= 1))
    # warmup rows are uniform-random; mixed masks work
    mixed = agent.act_batch(states, np.full(5, 0.5),
                            np.asarray([1, 0, 1, 0, 0], bool))
    assert mixed.shape == (5, 3)
    assert np.all((mixed >= 0) & (mixed <= 1))


def test_act_batch_sigma_zero_is_deterministic():
    cfg = DDPGConfig(state_dim=8, action_dim=2)
    agent = DDPGAgent(cfg, seed=0)
    states = np.random.default_rng(1).random((4, 8)).astype(np.float32)
    a1 = agent.act_batch(states, np.zeros(4), np.zeros(4, bool))
    a2 = np.stack([agent.act(states[i], 0.0) for i in range(4)])
    np.testing.assert_allclose(a1, a2, atol=1e-6)


@pytest.mark.parametrize("capacity,chunks", [
    (64, (40,)),          # vectorized write, no wraparound
    (32, (20, 20, 20)),   # vectorized writes that wrap the ring
    (16, (40,)),          # oversized batch -> scalar fallback
])
def test_push_batch_equals_sequential_push(capacity, chunks):
    rng = np.random.default_rng(5)
    sd, ad = 6, 2
    one = ReplayBuffer(capacity, sd, ad, seed=0)
    two = ReplayBuffer(capacity, sd, ad, seed=0)
    for n in chunks:
        s = rng.random((n, sd)).astype(np.float32)
        a = rng.random((n, ad)).astype(np.float32)
        r = rng.random(n).astype(np.float32)
        s2 = rng.random((n, sd)).astype(np.float32)
        d = (rng.random(n) > 0.5).astype(np.float32)
        for i in range(n):
            one.push(s[i], a[i], r[i], s2[i], d[i])
        two.push_batch(s, a, r, s2, d)
    assert one.ptr == two.ptr and one.size == two.size
    np.testing.assert_array_equal(one.states, two.states)
    np.testing.assert_array_equal(one.actions, two.actions)
    np.testing.assert_array_equal(one.rewards, two.rewards)
    np.testing.assert_array_equal(one.next_states, two.next_states)
    np.testing.assert_array_equal(one.dones, two.dones)


# ------------------------------------------------------------ the engine

@pytest.mark.parametrize("methods", ["p", "q", "pq"])
def test_batched_search_runs_all_agents(tiny_lm, methods):
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=6,
        reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=16, buffer_size=256))
    search = BatchedCompressionSearch(cm, batch, scfg, ctx, batch_size=4)
    res = search.run()
    assert len(res.history) == 6
    assert [r.episode for r in res.history] == list(range(6))
    for rec in res.history:
        assert np.isfinite(rec.reward)
        assert 0.0 <= rec.accuracy <= 1.0
        assert rec.latency_s > 0
        assert len(rec.policy.cmps) == len(search.specs)
    # shared-episode-reward transitions, all pushed
    assert len(search.replay) == min(256, 6 * len(search.steps))


def test_batched_search_policies_legal(tiny_lm):
    search = _mk_search(tiny_lm, cls=BatchedCompressionSearch,
                        batch_size=3)
    for rec in search.run_episode_batch(0, 3):
        for s, c in zip(search.specs, rec.policy.cmps):
            if s.prunable and s.prune_dim:
                assert c.keep % s.prune_granularity == 0 \
                    or c.keep == s.prune_dim
            if c.mode == "MIX":
                assert s.mix_supported
            if not s.quantizable:
                assert c.mode == "FP32"


def test_batched_search_sigma_schedule(tiny_lm):
    """Each episode in a batch keeps its own sigma/warmup position."""
    search = _mk_search(tiny_lm, cls=BatchedCompressionSearch,
                        batch_size=6)
    recs = search.run_episode_batch(0, 6)
    want = [search.agent.sigma_at(e) for e in range(6)]
    got = [r.sigma for r in recs]
    np.testing.assert_allclose(got, want, atol=1e-6)


# -------------------------------------------------------- the population

def _mk_population_member(tiny_lm, methods, batch_size=3):
    """Batched member with action_dim padded to the pq maximum so
    p/q/pq agents stack into one vmappable population."""
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=6, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=16, buffer_size=256, action_dim=3))
    return BatchedCompressionSearch(cm, batch, scfg, ctx,
                                    batch_size=batch_size)


def test_population_runs_mixed_methods(tiny_lm):
    """p/q/pq members share update dispatches; per-member histories keep
    scalar-engine semantics (episode order, sigma schedule, legality)."""
    members = [_mk_population_member(tiny_lm, m) for m in ("p", "q", "pq")]
    pop = PopulationSearch(members)
    results = pop.run(episodes=6)
    assert len(results) == 3
    for m, res in zip(members, results):
        assert [r.episode for r in res.history] == list(range(6))
        want = [m.agent.sigma_at(e) for e in range(6)]
        np.testing.assert_allclose([r.sigma for r in res.history], want,
                                   atol=1e-6)
        for rec in res.history:
            assert np.isfinite(rec.reward)
            assert len(rec.policy.cmps) == len(m.specs)
        # updates ran (post-warmup budgets were dispatched and cleared)
        assert m._pending_updates == 0
        assert not m._defer_updates
    # padded action dims: all members share the pq agent shape
    assert len({m.agent.cfg.action_dim for m in members}) == 1


def test_population_warmup_matches_independent(tiny_lm):
    """Before any update fires, a population member's rollout equals the
    same search run independently (identical seeds -> identical RNG)."""
    member = _mk_population_member(tiny_lm, "pq")
    solo = _mk_population_member(tiny_lm, "pq")
    pop_recs = PopulationSearch([member]).run(episodes=2)[0].history
    solo_recs = solo.run(episodes=2).history
    for a, b in zip(pop_recs, solo_recs):
        assert a.reward == pytest.approx(b.reward, abs=1e-6)
        assert a.accuracy == pytest.approx(b.accuracy, abs=1e-6)
        assert a.latency_s == pytest.approx(b.latency_s, rel=1e-9)


def test_population_rejects_mismatched_configs(tiny_lm):
    native_pq = _mk_population_member(tiny_lm, "pq")
    cm, batch = tiny_lm
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods="p", episodes=6, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=2, updates_per_episode=2,
                        batch_size=16, buffer_size=256))   # native dims
    native_p = BatchedCompressionSearch(cm, batch, scfg, ctx, batch_size=3)
    with pytest.raises(ValueError):
        PopulationSearch([native_pq, native_p])
    with pytest.raises(ValueError):
        PopulationSearch([])
