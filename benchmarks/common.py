"""Shared benchmark substrate: the two Galen search testbeds.

* LM testbed  — 4L/128d transformer trained on the Zipfian-bigram language
  (the LM-serving analogue of the paper's ResNet18/CIFAR-10: small enough
  to train on one CPU core in ~2 min, accuracy degrades measurably under
  compression).
* ResNet testbed — the paper's own model family on blob images.

Trained weights are cached under artifacts/ so every benchmark and test
reuses one training run. The latency-oracle context is the batch-1 decode
scenario (single-stream serving — the embedded-device analogue).
"""
from __future__ import annotations

import os
import pickle

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.latency import LatencyContext
from repro.models.resnet import ResNetConfig

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# d_model=256 keeps every unit 256-aligned so the MIX (int4) option is
# hardware-legal everywhere — the full paper action space is reachable.
LM_CFG = ArchConfig(name="testbed-lm", num_layers=4, d_model=256,
                    num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
                    vocab_size=256, scan_layers=True)

RESNET_CFG = ResNetConfig(name="testbed-resnet", stages=(2, 2, 2),
                          widths=(16, 32, 64), num_classes=10, img_size=16)

# single-stream serving on one v5e chip — the "Raspberry Pi" of this repo
SERVE_CTX = LatencyContext(tokens=1, seq_ctx=512, mode="decode", batch=1)
# image-classification context for the ResNet testbed (per-image latency)
IMG_CTX = LatencyContext(tokens=1, seq_ctx=0, mode="prefill", batch=1)


def _cache(path, builder):
    os.makedirs(ART, exist_ok=True)
    f = os.path.join(ART, path)
    if os.path.exists(f):
        with open(f, "rb") as fh:
            return pickle.load(fh)
    obj = builder()
    with open(f, "wb") as fh:
        pickle.dump(obj, fh)
    return obj


def get_lm_testbed(steps: int = 220):
    """Returns (cfg, params, val_batch, clean_accuracy)."""

    def build():
        from repro.train.trainer import train_testbed_lm
        params, val, acc = train_testbed_lm(LM_CFG, steps=steps, batch=16,
                                            seq=48)
        return {"params": jax.device_get(params),
                "val": jax.device_get(val), "acc": acc}

    d = _cache("testbed_lm.pkl", build)
    params = jax.tree.map(jnp.asarray, d["params"])
    val = jax.tree.map(jnp.asarray, d["val"])
    return LM_CFG, params, val, d["acc"]


def get_resnet_testbed(steps: int = 200):
    def build():
        from repro.train.trainer import train_testbed_resnet
        params, val, acc = train_testbed_resnet(RESNET_CFG, steps=steps,
                                                batch=64)
        return {"params": jax.device_get(params),
                "val": jax.device_get(val), "acc": acc}

    d = _cache("testbed_resnet.pkl", build)
    params = jax.tree.map(jnp.asarray, d["params"])
    val = jax.tree.map(jnp.asarray, d["val"])
    return RESNET_CFG, params, val, d["acc"]
