"""Benchmark regression gate: fail CI when episodes/sec drops vs the
committed baseline.

``python -m benchmarks.regression_gate`` compares the rows of a freshly
generated ``artifacts/bench_engine.json`` (``benchmarks.search_setup``)
against the committed ``artifacts/bench_baseline.json`` and exits
nonzero if any matched row's throughput metric regressed by more than
``--tol`` (default 20%). Rows are matched on their identity fields
(table/engine/members/batch_size/updates_per_episode); rows present in
only one file are skipped — adding a new engine never breaks the gate,
and the baseline only tightens when it is re-committed from a fresh
measurement on the reference box.

The weekly CI job runs this right after the benchmark. Shared runners
are noisy; the 20% tolerance plus best-of-N timing in the benchmark
keeps the gate quiet on contention while still catching real
dispatch-count or compile-path regressions (which cost 2x+, not 20%).
"""
from __future__ import annotations

import argparse
import json
import sys

KEY_FIELDS = ("table", "engine", "members", "batch_size",
              "updates_per_episode")
METRICS = ("eps_per_s", "independent_eps_per_s", "population_eps_per_s",
           "runs_per_s")


def row_key(row: dict) -> tuple:
    return tuple(json.dumps(row.get(f)) for f in KEY_FIELDS)


def check(current: list, baseline: list, tol: float):
    """(checked metric count, failure strings)."""
    base = {row_key(r): r for r in baseline}
    checked, failures = 0, []
    for row in current:
        b = base.get(row_key(row))
        if b is None:
            continue
        for m in METRICS:
            if m not in row or m not in b or not b[m] > 0:
                continue
            checked += 1
            if row[m] < (1.0 - tol) * b[m]:
                ident = {f: row.get(f) for f in KEY_FIELDS
                         if row.get(f) is not None}
                failures.append(
                    f"{ident}: {m} {row[m]:.2f} < "
                    f"{(1.0 - tol) * b[m]:.2f} "
                    f"(baseline {b[m]:.2f}, tol {tol:.0%})")
    return checked, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="artifacts/bench_engine.json")
    ap.add_argument("--baseline", default="artifacts/bench_baseline.json")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional regression (default 0.2)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    checked, failures = check(current, baseline, args.tol)
    if not checked:
        print("regression gate: no comparable rows — baseline stale?",
              file=sys.stderr)
        return 2
    for line in failures:
        print(f"REGRESSION {line}", file=sys.stderr)
    print(f"regression gate: {checked} metrics checked, "
          f"{len(failures)} regressions (tol {args.tol:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
