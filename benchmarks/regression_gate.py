"""Benchmark regression gate: fail CI when episodes/sec drops vs the
committed baseline.

``python -m benchmarks.regression_gate`` compares the rows of a freshly
generated ``artifacts/bench_engine.json`` (``benchmarks.search_setup``)
against the committed ``artifacts/bench_baseline.json`` and exits
nonzero if any matched row's throughput metric regressed by more than
``--tol`` (default 20%). Rows are matched on their identity fields
(table/engine/members/batch_size/updates_per_episode); rows present in
only one file are skipped — adding a new engine never breaks the gate,
and the baseline only tightens when it is re-committed from a fresh
measurement on the reference box.

The weekly CI job runs this right after the benchmark. Shared runners
are noisy; the 20% tolerance plus best-of-N timing in the benchmark
keeps the gate quiet on contention while still catching real
dispatch-count or compile-path regressions (which cost 2x+, not 20%).

Calibration drift (``--calib-current``/``--calib-baseline``): compares a
fresh ``benchmarks.calibrate_oracle`` artifact against the committed
``artifacts/latency_calibration.json``. Two checks:

* every demo row must be within its own stated tolerance (the
  end-to-end predicted-vs-measured acceptance criterion travels with
  the artifact);
* per-(kind, container) ratios, NORMALIZED by that kind's raw-container
  ratio so absolute box speed cancels, must agree with the baseline
  within ``--calib-tol`` in log space — this catches a deploy-path or
  cost-model change that moves int8/int4 relative cost, while staying
  quiet when the runner is simply a faster or slower machine.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

KEY_FIELDS = ("table", "engine", "members", "batch_size",
              "updates_per_episode")
METRICS = ("eps_per_s", "independent_eps_per_s", "population_eps_per_s",
           "runs_per_s", "ms_per_update", "serve_tok_per_s")
# latency-type metrics: a REGRESSION is the value going UP
LOWER_IS_BETTER = frozenset({"ms_per_update"})


def row_key(row: dict) -> tuple:
    return tuple(json.dumps(row.get(f)) for f in KEY_FIELDS)


def check(current: list, baseline: list, tol: float, metric: str = ""):
    """(checked metric count, failure strings). ``metric`` restricts the
    gate to one metric name (e.g. ``ms_per_update``)."""
    base = {row_key(r): r for r in baseline}
    metrics = (metric,) if metric else METRICS
    checked, failures = 0, []
    for row in current:
        b = base.get(row_key(row))
        if b is None:
            continue
        for m in metrics:
            if m not in row or m not in b or not b[m] > 0:
                continue
            checked += 1
            if m in LOWER_IS_BETTER:
                if row[m] > (1.0 + tol) * b[m]:
                    ident = {f: row.get(f) for f in KEY_FIELDS
                             if row.get(f) is not None}
                    failures.append(
                        f"{ident}: {m} {row[m]:.2f} > "
                        f"{(1.0 + tol) * b[m]:.2f} "
                        f"(baseline {b[m]:.2f}, tol {tol:.0%}, "
                        f"lower is better)")
            elif row[m] < (1.0 - tol) * b[m]:
                ident = {f: row.get(f) for f in KEY_FIELDS
                         if row.get(f) is not None}
                failures.append(
                    f"{ident}: {m} {row[m]:.2f} < "
                    f"{(1.0 - tol) * b[m]:.2f} "
                    f"(baseline {b[m]:.2f}, tol {tol:.0%})")
    return checked, failures


def _normalized_ratios(artifact: dict) -> dict:
    """(kind, container) -> ratio / ratio[kind]["raw"]. Dividing by the
    raw-container ratio of the SAME kind cancels the host's absolute
    speed (both numerator and denominator carry it), leaving only the
    relative cost of the integer container — the thing the oracle's
    ranking depends on."""
    out = {}
    for kind, d in artifact.get("ratios", {}).items():
        raw = d.get("raw")
        if not raw or raw <= 0:
            continue
        for c, v in d.items():
            if c != "raw" and v > 0:
                out[(kind, c)] = v / raw
    return out


def check_calibration(current: dict, baseline: dict, tol: float):
    """(checked count, failure strings) for calibration drift."""
    checked, failures = 0, []
    for r in current.get("demo", []):
        checked += 1
        if not r.get("within_tol", False):
            failures.append(
                f"demo[{r.get('container')}]: predicted_ratio "
                f"{r.get('predicted_ratio', float('nan')):.3f} vs "
                f"measured_ratio "
                f"{r.get('measured_ratio', float('nan')):.3f} exceeds "
                f"artifact tolerance {r.get('tolerance')}")
    cur = _normalized_ratios(current)
    base = _normalized_ratios(baseline)
    bound = math.log1p(tol)
    for key in sorted(set(cur) & set(base), key=str):
        checked += 1
        drift = abs(math.log(cur[key] / base[key]))
        if drift > bound:
            failures.append(
                f"calib {key}: normalized ratio {cur[key]:.3g} vs "
                f"baseline {base[key]:.3g} "
                f"(|log drift| {drift:.2f} > {bound:.2f})")
    return checked, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="artifacts/bench_engine.json")
    ap.add_argument("--baseline", default="artifacts/bench_baseline.json")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional regression (default 0.2)")
    ap.add_argument("--metric", default="",
                    help="gate only this metric (e.g. ms_per_update; "
                         "lower-is-better metrics invert the check)")
    ap.add_argument("--calib-current", default="",
                    help="fresh calibrate_oracle artifact to drift-check")
    ap.add_argument("--calib-baseline",
                    default="artifacts/latency_calibration.json")
    ap.add_argument("--calib-tol", type=float, default=0.5,
                    help="allowed normalized-ratio drift (default 0.5)")
    ap.add_argument("--calib-only", action="store_true",
                    help="skip the throughput gate")
    args = ap.parse_args(argv)
    checked, failures = 0, []
    if not args.calib_only:
        with open(args.current) as f:
            current = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
        checked, failures = check(current, baseline, args.tol,
                                  metric=args.metric)
    if args.calib_current:
        with open(args.calib_current) as f:
            ccur = json.load(f)
        with open(args.calib_baseline) as f:
            cbase = json.load(f)
        c2, f2 = check_calibration(ccur, cbase, args.calib_tol)
        checked += c2
        failures += f2
    if not checked:
        print("regression gate: no comparable rows — baseline stale?",
              file=sys.stderr)
        return 2
    for line in failures:
        print(f"REGRESSION {line}", file=sys.stderr)
    print(f"regression gate: {checked} metrics checked, "
          f"{len(failures)} regressions (tol {args.tol:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
