"""Search construction shared by the paper-table benchmarks."""
from __future__ import annotations

import os

from benchmarks.common import IMG_CTX, SERVE_CTX, get_lm_testbed, \
    get_resnet_testbed
from repro.core.compress import CompressibleLM, CompressibleResNet
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import CompressionSearch, SearchConfig
from repro.core.sensitivity import run_sensitivity

FULL = os.environ.get("GALEN_BENCH_FULL", "0") == "1"

# paper: 310 (quant) / 410 (prune, joint) episodes, 10 warm-up.
EPISODES = {"p": 410, "q": 310, "pq": 410} if FULL else \
    {"p": 60, "q": 50, "pq": 60}
WARMUP = 10
UPDATES = 48 if FULL else 24

_sens_cache = {}


def lm_search(methods: str, c: float, seed: int = 0, episodes=None,
              sens_enabled: bool = True) -> CompressionSearch:
    cfg, params, val, acc = get_lm_testbed()
    # smaller eval batch: ~2x faster episodes, ±2% accuracy noise (the
    # paper also validates on a small split during search)
    val = {k: v[:32] for k, v in val.items()}
    cm = CompressibleLM(cfg, params)
    key = ("lm", sens_enabled)
    if key not in _sens_cache:
        if sens_enabled:
            _sens_cache[key] = run_sensitivity(cm, val)
        else:
            from repro.core.sensitivity import SensitivityResult
            _sens_cache[key] = SensitivityResult(
                {s.name: {} for s in cm.specs})  # constant features
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000),
        seed=seed)
    return CompressionSearch(cm, val, scfg, SERVE_CTX,
                             sens=_sens_cache[key])


def resnet_search(methods: str, c: float, seed: int = 0,
                  episodes=None) -> CompressionSearch:
    rcfg, params, val, acc = get_resnet_testbed()
    cm = CompressibleResNet(rcfg, params)
    if "resnet" not in _sens_cache:
        _sens_cache["resnet"] = run_sensitivity(cm, val)
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000),
        seed=seed)
    return CompressionSearch(cm, val, scfg, IMG_CTX,
                             sens=_sens_cache["resnet"])
