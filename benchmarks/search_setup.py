"""Search construction shared by the paper-table benchmarks, plus the
episode-engine throughput comparisons: scalar vs batched vs fused vs
epoch-fused rollouts, and independent vs population-shared (vmapped)
agent updates.

``python -m benchmarks.search_setup`` prints episodes/sec for all of
them — plus the sequential-vs-fused sensitivity-analysis timing
(``sensitivity_comparison``, best-of-5 interleaved, with the
1-execution dispatch bound asserted) — and writes one row per engine
to ``artifacts/bench_engine.json``
(uploaded weekly by CI; ``benchmarks.regression_gate`` fails the job
when a row regresses >20% vs the committed
``artifacts/bench_baseline.json``)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from contextlib import contextmanager

from benchmarks.common import IMG_CTX, SERVE_CTX, get_lm_testbed, \
    get_resnet_testbed
from repro.core.compress import CompressibleLM, CompressibleResNet
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import (BatchedCompressionSearch, CompressionSearch,
                               FusedCompressionSearch, PopulationSearch,
                               SearchConfig)
from repro.core.sensitivity import (run_sensitivity,
                                    run_sensitivity_sequential)

ENGINES = {"scalar": CompressionSearch, "batched": BatchedCompressionSearch,
           "fused": FusedCompressionSearch, "epoch": FusedCompressionSearch}

# batches fused into one dispatch by the epoch engine rows
EPOCH_BATCHES = 4

FULL = os.environ.get("GALEN_BENCH_FULL", "0") == "1"

# paper: 310 (quant) / 410 (prune, joint) episodes, 10 warm-up.
EPISODES = {"p": 410, "q": 310, "pq": 410} if FULL else \
    {"p": 60, "q": 50, "pq": 60}
WARMUP = 10
UPDATES = 48 if FULL else 24

_sens_cache = {}


def lm_search(methods: str, c: float, seed: int = 0, episodes=None,
              sens_enabled: bool = True, cls=CompressionSearch,
              action_dim: int = 0, **cls_kw) -> CompressionSearch:
    """``action_dim`` > the method's native count pads the agent's
    action space (required for mixed-method PopulationSearch members)."""
    cfg, params, val, acc = get_lm_testbed()
    # smaller eval batch: ~2x faster episodes, ±2% accuracy noise (the
    # paper also validates on a small split during search)
    val = {k: v[:32] for k, v in val.items()}
    cm = CompressibleLM(cfg, params)
    key = ("lm", sens_enabled)
    if key not in _sens_cache:
        if sens_enabled:
            _sens_cache[key] = run_sensitivity(cm, val)
        else:
            from repro.core.sensitivity import SensitivityResult
            _sens_cache[key] = SensitivityResult(
                {s.name: {} for s in cm.specs})  # constant features
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000,
                        action_dim=action_dim or 1),
        seed=seed)
    return cls(cm, val, scfg, SERVE_CTX, sens=_sens_cache[key], **cls_kw)


def lm_batched_search(methods: str, c: float, seed: int = 0, episodes=None,
                      sens_enabled: bool = True,
                      batch_size: int = 8) -> BatchedCompressionSearch:
    """lm_search with the batched episode engine (K episodes/rollout)."""
    return lm_search(methods, c, seed=seed, episodes=episodes,
                     sens_enabled=sens_enabled,
                     cls=BatchedCompressionSearch, batch_size=batch_size)


def lm_fused_search(methods: str, c: float, seed: int = 0, episodes=None,
                    sens_enabled: bool = True,
                    batch_size: int = 8) -> FusedCompressionSearch:
    """lm_search with the fused engine (whole rollout = one dispatch)."""
    return lm_search(methods, c, seed=seed, episodes=episodes,
                     sens_enabled=sens_enabled,
                     cls=FusedCompressionSearch, batch_size=batch_size)


def resnet_search(methods: str, c: float, seed: int = 0,
                  episodes=None) -> CompressionSearch:
    rcfg, params, val, acc = get_resnet_testbed()
    cm = CompressibleResNet(rcfg, params)
    if "resnet" not in _sens_cache:
        _sens_cache["resnet"] = run_sensitivity(cm, val)
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000),
        seed=seed)
    return CompressionSearch(cm, val, scfg, IMG_CTX,
                             sens=_sens_cache["resnet"])


# ===========================================================================
# Episode-engine throughput: scalar loop vs batched rollout
# ===========================================================================

_tiny_testbed_cache = {}


def _tiny_testbed():
    """Tiny untrained LM + shared sensitivity — engine overhead
    dominates its episodes, which is what these comparisons isolate."""
    if "lm" not in _tiny_testbed_cache:
        import jax
        from repro.configs.base import ArchConfig
        from repro.data.pipeline import bigram_lm
        from repro.models import model as M

        cfg = ArchConfig(name="tiny-engine", num_layers=3, d_model=64,
                         num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256,
                         vocab_size=128, scan_layers=True)
        params = M.init(cfg, jax.random.PRNGKey(0))
        batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
        _tiny_testbed_cache["lm"] = (CompressibleLM(cfg, params), batch)
    return _tiny_testbed_cache["lm"]


def _tiny_engine(engine, batch_size: int, updates: int,
                 methods: str = "pq", action_dim: int = 0, seed: int = 0,
                 calib=None):
    """``engine``: "scalar" | "batched" | "fused" | "epoch" (bools kept
    for the original scalar/batched call sites). ``calib`` switches the
    engine to ``oracle_mode="calibrated"`` with that table."""
    if isinstance(engine, bool):
        engine = "batched" if engine else "scalar"
    cm, batch = _tiny_testbed()
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=64, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=4, updates_per_episode=updates,
                        batch_size=16, buffer_size=512,
                        action_dim=action_dim or 1),
        seed=seed,
        oracle_mode="calibrated" if calib is not None else "analytic")
    cls = ENGINES[engine]
    kw = {} if calib is None else {"calib": calib}
    if engine == "scalar":
        return cls(cm, batch, scfg, ctx, **kw)
    if engine == "epoch":
        return cls(cm, batch, scfg, ctx, batch_size=batch_size,
                   epoch_batches=EPOCH_BATCHES, **kw)
    return cls(cm, batch, scfg, ctx, batch_size=batch_size, **kw)


def synthetic_calibration():
    """Non-unity correction factors for every tiny-LM unit kind — a
    stand-in for the committed artifact that makes it observable (in
    unit tests and the dispatch probe) that the factors really entered
    the trace."""
    from repro.core.measure import CalibrationTable
    ratios = {k: {"raw": 1.1, "int8": 1.7, "int4": 2.3}
              for k in ("embed", "attn_qkv", "attn_out", "mlp_up",
                        "mlp_down", "head")}
    return CalibrationTable(ratios=ratios,
                            extra={"attn": 1.4, "overhead": 1.4},
                            meta={"synthetic": True})


def calibrated_fused_row(batch_size: int = 8, updates: int = 8) -> dict:
    """ISSUE 6 acceptance: ``oracle_mode="calibrated"`` must keep the
    fused engine at the same <=4-dispatch, zero-host-step bound as the
    analytic oracle — the correction factors bake into the trace as
    constants, they never add dispatches."""
    s = _tiny_engine("fused", batch_size, updates,
                     calib=synthetic_calibration())
    s.run(episodes=16)                          # warm the jit caches
    counts = assert_fused_dispatch_count(s, first_episode=16,
                                         batch_size=batch_size)
    return {"table": "engine", "engine": "fused_calibrated",
            "batch_size": batch_size, "updates_per_episode": updates,
            "dispatches_per_batch": sum(
                counts[k] for k in ("rollout", "validate", "push",
                                    "update"))}


def episodes_per_sec(search, episodes: int = 32,
                     warmup_episodes: int = 16, repeats: int = 3) -> float:
    # warm the jit caches over TWO chunks: the first chunk straddles the
    # agent's warmup boundary, so its fused update chunk is shorter than
    # the steady-state one — both scan lengths must compile here, not in
    # the timed region
    search.run(episodes=warmup_episodes)
    # best-of-N: shared CI/dev boxes show ±20% run-to-run contention
    # noise, and the minimum is the stable estimate of engine cost
    import jax
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        search.run(episodes=episodes)
        # the final fused update chunk is dispatched asynchronously —
        # fence it so the timed region contains all of its work
        jax.block_until_ready(search.agent.state)
        best = min(best, time.perf_counter() - t0)
    return episodes / best


@contextmanager
def fused_dispatch_probe(search):
    """Compile-counter hook: counts REAL invocations of the fused
    path's compiled entry points (rollout jit, fused validation jit,
    replay ring-write jit, update-chunk jit) by wrapping the callables
    themselves — not trusting the engine's own ``dispatch_log`` — and
    plants canaries on the per-step host path (``act_batch``, the numpy
    batch oracle) so a regression that silently falls back to L host
    steps per batch is caught even though it makes no jit calls."""
    import repro.core.ddpg as ddpg_mod
    import repro.core.replay as replay_mod
    import repro.core.search as search_mod
    counts = {"rollout": 0, "validate": 0, "push": 0, "update": 0,
              "host_steps": 0}
    saved = []

    def wrap(obj, name, key):
        fn = getattr(obj, name)
        saved.append((obj, name, name in vars(obj), fn))

        def counting(*a, **kw):
            counts[key] += 1
            return fn(*a, **kw)

        setattr(obj, name, counting)

    wrap(search, "_rollout", "rollout")
    wrap(search.cmodel, "accuracy_policy_batch", "validate")
    wrap(replay_mod, "_device_push", "push")
    wrap(ddpg_mod, "_update_chunk_jit", "update")
    # canaries — the numpy engines' per-unit-step host machinery
    wrap(search.agent, "act_batch", "host_steps")
    wrap(search_mod, "policy_latency_batch", "host_steps")
    try:
        yield counts
    finally:
        for obj, name, was_own, fn in reversed(saved):
            if was_own:
                setattr(obj, name, fn)
            else:
                delattr(obj, name)


def assert_fused_dispatch_count(search, first_episode: int,
                                batch_size: int) -> dict:
    """One post-compile episode batch on the fused engine must stay
    within the ISSUE 3 bound: rollout + validation + ring write +
    update chunk <= 4 jit executions, zero per-step host work. Also
    checks the engine's ``dispatch_log`` agrees with the measured
    counts. Runs in the weekly job; a regression fails it."""
    search.dispatch_log.clear()
    with fused_dispatch_probe(search) as counts:
        search.run_episode_batch(first_episode, batch_size)
        search._flush_updates()
    total = sum(counts[k] for k in ("rollout", "validate", "push",
                                    "update"))
    assert counts["host_steps"] == 0, \
        f"per-step host path ran under the fused engine: {counts}"
    assert total <= 4, f"fused engine made {total} dispatches: {counts}"
    assert len(search.dispatch_log) == total, \
        f"dispatch_log {search.dispatch_log} != measured {counts}"
    return counts


@contextmanager
def epoch_dispatch_probe(search):
    """Epoch-mode compile-counter hook: counts REAL invocations of the
    cached epoch executables (by wrapping the compiled callables in the
    engine's FIFO cache), and plants canaries on EVERY per-batch entry
    point — the fused rollout jit, the standalone validation jit, the
    ring-write jit, the update-chunk jit, and the numpy engines' host
    machinery. An epoch must touch none of them: the whole E-batch
    epoch is the one compiled program."""
    import repro.core.ddpg as ddpg_mod
    import repro.core.replay as replay_mod
    import repro.core.search as search_mod
    counts = {"epoch": 0, "rollout": 0, "validate": 0, "push": 0,
              "update": 0, "host_steps": 0}
    saved = []

    def wrap(obj, name, key):
        fn = getattr(obj, name)
        saved.append((obj, name, name in vars(obj), fn))

        def counting(*a, **kw):
            counts[key] += 1
            return fn(*a, **kw)

        setattr(obj, name, counting)

    # the compiled epoch executables live in the engine's FIFO cache as
    # (params, fn) hits — wrap each fn in place
    cache_saved = dict(search._epoch_cache)

    def wrap_cache_entry(k, params, fn):
        def counting(*a, **kw):
            counts["epoch"] += 1
            return fn(*a, **kw)

        search._epoch_cache[k] = (params, counting)

    for k, (params, fn) in cache_saved.items():
        wrap_cache_entry(k, params, fn)
    # canaries: the per-batch fused path and the numpy host path
    wrap(search, "_rollout", "rollout")
    wrap(search.cmodel, "accuracy_policy_batch", "validate")
    wrap(replay_mod, "_device_push", "push")
    wrap(ddpg_mod, "_update_chunk_jit", "update")
    wrap(search.agent, "act_batch", "host_steps")
    wrap(search_mod, "policy_latency_batch", "host_steps")
    try:
        yield counts
    finally:
        for obj, name, was_own, fn in reversed(saved):
            if was_own:
                setattr(obj, name, fn)
            else:
                delattr(obj, name)
        search._epoch_cache.update(cache_saved)


def assert_epoch_dispatch_count(search, first_episode: int,
                                n_batches: int) -> dict:
    """One post-compile epoch on the epoch-fused engine must be ONE jit
    execution total (the ISSUE 4 acceptance bound): the epoch
    executable once, the per-batch compiled entry points and the host
    path never. Also checks the engine's ``dispatch_log`` agrees. Runs
    in the weekly job; a regression fails it."""
    search.dispatch_log.clear()
    with epoch_dispatch_probe(search) as counts:
        search.run_epoch(first_episode, n_batches)
    assert counts["host_steps"] == 0, \
        f"per-step host path ran under the epoch engine: {counts}"
    per_batch = sum(counts[k] for k in ("rollout", "validate", "push",
                                        "update"))
    assert per_batch == 0, \
        f"per-batch compiled entry points ran in an epoch: {counts}"
    assert counts["epoch"] == 1, \
        f"epoch made {counts['epoch']} epoch executions " \
        f"(uncached schedule?): {counts}"
    assert search.dispatch_log == ["epoch"], search.dispatch_log
    return counts


@contextmanager
def population_epoch_dispatch_probe(pop):
    """Shared-epoch compile-counter hook for ``PopulationSearch`` /
    ``FleetSearch``: counts REAL invocations of the population's compiled
    epoch executables (wrapping the callables in ``_pop_epoch_cache``)
    and plants canaries on every fallback — the members' own epoch
    caches (the per-member decomposition), the per-batch fused entry
    points, and the numpy host path. One population epoch must execute
    the shared program exactly once and touch nothing else, mesh-sharded
    or not."""
    import repro.core.ddpg as ddpg_mod
    import repro.core.replay as replay_mod
    import repro.core.search as search_mod
    counts = {"pop_epoch": 0, "member_epoch": 0, "rollout": 0,
              "validate": 0, "push": 0, "update": 0, "host_steps": 0}
    saved = []

    def wrap(obj, name, key):
        fn = getattr(obj, name)
        saved.append((obj, name, name in vars(obj), fn))

        def counting(*a, **kw):
            counts[key] += 1
            return fn(*a, **kw)

        setattr(obj, name, counting)

    def wrap_cache(cache, key):
        before = dict(cache)
        for k, (params, fn) in before.items():
            def make(fn):
                def counting(*a, **kw):
                    counts[key] += 1
                    return fn(*a, **kw)
                return counting
            cache[k] = (params, make(fn))
        return before

    pop_saved = wrap_cache(pop._pop_epoch_cache, "pop_epoch")
    member_saved = [(m, wrap_cache(m._epoch_cache, "member_epoch"))
                    for m in pop.members]
    m0 = pop.members[0]
    wrap(m0, "_rollout", "rollout")
    wrap(m0.cmodel, "accuracy_policy_batch", "validate")
    wrap(replay_mod, "_device_push", "push")
    wrap(ddpg_mod, "_update_chunk_jit", "update")
    wrap(m0.agent, "act_batch", "host_steps")
    wrap(search_mod, "policy_latency_batch", "host_steps")
    try:
        yield counts
    finally:
        for obj, name, was_own, fn in reversed(saved):
            if was_own:
                setattr(obj, name, fn)
            else:
                delattr(obj, name)
        pop._pop_epoch_cache.update(pop_saved)
        for m, cs in member_saved:
            m._epoch_cache.update(cs)


def assert_population_epoch_dispatch_count(pop, first_episode: int,
                                           n_batches: int) -> dict:
    """One post-compile population epoch must be ONE execution of the
    shared vmapped epoch executable — never the per-member epoch
    decomposition, the per-batch entry points, or the host path — and
    every member's dispatch_log must record the one shared dispatch.
    Holds identically for the mesh-sharded ``FleetSearch`` (the sharded
    program is the same cached executable compiled for sharded
    operands). Runs in the fleet tests and the weekly job."""
    for m in pop.members:
        m.dispatch_log.clear()
    with population_epoch_dispatch_probe(pop) as counts:
        pop.run_epoch(first_episode, n_batches)
    assert counts["host_steps"] == 0, \
        f"host path ran under the population epoch: {counts}"
    per_batch = sum(counts[k] for k in ("rollout", "validate", "push",
                                        "update"))
    assert per_batch == 0 and counts["member_epoch"] == 0, \
        f"population epoch fell back off the shared dispatch: {counts}"
    assert counts["pop_epoch"] == 1, \
        f"population epoch made {counts['pop_epoch']} shared executions " \
        f"(uncached schedule?): {counts}"
    for m in pop.members:
        assert m.dispatch_log == ["epoch"], m.dispatch_log
    return counts


@contextmanager
def sensitivity_dispatch_probe():
    """Compile-counter hook for the sensitivity subsystem: counts REAL
    executions of the fused layer×probe program (by wrapping the module
    indirection the compiled callable is dispatched through) and plants
    a canary on the sequential path's per-probe evaluations — a fused
    analysis that silently falls back to L×probe dispatches is caught
    even though each one is a legitimate jit call."""
    import repro.core.sensitivity as sens_mod
    counts = {"fused": 0, "seq_probes": 0}
    saved_f, saved_s = sens_mod._fused_dispatch, sens_mod._seq_eval

    def fused(fn, *a):
        counts["fused"] += 1
        return saved_f(fn, *a)

    def seq(fn, cs):
        counts["seq_probes"] += 1
        return saved_s(fn, cs)

    sens_mod._fused_dispatch, sens_mod._seq_eval = fused, seq
    try:
        yield counts
    finally:
        sens_mod._fused_dispatch, sens_mod._seq_eval = saved_f, saved_s


def assert_sensitivity_dispatch_count(cmodel, batch) -> dict:
    """One post-compile ``run_sensitivity`` must be ONE jit execution of
    the fused program and ZERO per-probe evaluations (the ISSUE 5
    acceptance bound, the sensitivity analogue of
    ``assert_epoch_dispatch_count``). Runs in the weekly job; a
    regression fails it."""
    run_sensitivity(cmodel, batch, memo=False)      # compile outside
    with sensitivity_dispatch_probe() as counts:
        run_sensitivity(cmodel, batch, memo=False)
    assert counts["seq_probes"] == 0, \
        f"per-probe sequential path ran under run_sensitivity: {counts}"
    assert counts["fused"] == 1, \
        f"run_sensitivity made {counts['fused']} fused executions: {counts}"
    return counts


def sensitivity_comparison(repeats: int = 5, verbose: bool = True) -> list:
    """Sequential vs fused ``run_sensitivity`` wall time on the tiny LM
    (the analysis every engine constructor pays), best-of-N interleaved
    round-robin like ``engine_comparison`` so box drift hits both arms
    equally. The throughput metric (analyses/sec) keeps the regression
    gate's lower-is-worse rule; the fused row also re-asserts the
    1-execution dispatch bound."""
    cm, batch = _tiny_testbed()
    arms = {"sequential": lambda: run_sensitivity_sequential(cm, batch),
            "fused": lambda: run_sensitivity(cm, batch, memo=False)}
    for fn in arms.values():
        fn()                                        # warm the jit caches
    best = {name: 0.0 for name in arms}
    for _ in range(repeats):
        for name, fn in arms.items():
            t0 = time.perf_counter()
            fn()                                    # result is host data
            best[name] = max(best[name],
                             1.0 / (time.perf_counter() - t0))
    assert_sensitivity_dispatch_count(cm, batch)
    rows = [{"table": "sensitivity", "engine": "sequential",
             "runs_per_s": round(best["sequential"], 3)},
            {"table": "sensitivity", "engine": "fused",
             "runs_per_s": round(best["fused"], 3),
             "dispatches_per_run": 1,
             "speedup_vs_sequential": round(
                 best["fused"] / best["sequential"], 2)}]
    if verbose:
        print(f"[sensitivity] sequential {best['sequential']:.2f} runs/s, "
              f"fused {best['fused']:.2f} runs/s -> "
              f"{best['fused'] / best['sequential']:.2f}x", flush=True)
    return rows


def engine_comparison(batch_size: int = 8, episodes: int = 32,
                      updates: int = 0, verbose: bool = True) -> list:
    """Episodes/sec on the tiny LM, one row per engine.

    ``updates=0`` isolates rollout+validation throughput — where the
    one-dispatch rollout pays off most; with updates enabled every
    engine dispatches each episode batch's updates as one fused
    ``update_chunk`` scan (PR 2), so the rollout engines amortize
    rollout AND learning dispatch. The epoch engine additionally fuses
    ``EPOCH_BATCHES`` whole batches (rollout+validate+push+update) into
    one jit execution with a single host readback.
    """
    import jax
    names = ("scalar", "batched", "fused", "epoch")
    searches = {}
    for name in names:
        s = _tiny_engine(name, batch_size, updates)
        # warm the jit caches over two chunks straddling the agent's
        # warmup boundary; the epoch engine warms a full run so the
        # timed chunks hit its compiled (warmup-straddling) schedule
        s.run(episodes=episodes if name == "epoch" else 16)
        jax.block_until_ready(s.agent.state)
        searches[name] = s
    # interleave the best-of-N repeats round-robin across engines so
    # box-level drift (thermal, contention) hits every engine equally
    # instead of penalizing whichever is measured last; N=5 because the
    # engines differ by less than this box's run-to-run spread
    eps = {n: 0.0 for n in names}
    for _ in range(5):
        for name, s in searches.items():
            t0 = time.perf_counter()
            s.run(episodes=episodes)
            # final dispatches are asynchronous — fence them into the
            # timed region
            jax.block_until_ready(s.agent.state)
            eps[name] = max(eps[name],
                            episodes / (time.perf_counter() - t0))
    rows = []
    for name in names:
        search = searches[name]
        row = {"table": "engine", "engine": name,
               "batch_size": batch_size, "episodes": episodes,
               "updates_per_episode": updates,
               "eps_per_s": round(eps[name], 2)}
        if name == "batched":
            row["speedup_vs_scalar"] = round(eps[name] / eps["scalar"],
                                             2)
        elif name == "fused":
            counts = assert_fused_dispatch_count(
                search, first_episode=2 * episodes,
                batch_size=batch_size)
            row["dispatches_per_batch"] = sum(
                counts[k] for k in ("rollout", "validate", "push",
                                    "update"))
            row["speedup_vs_batched"] = round(eps[name] / eps["batched"],
                                              2)
        elif name == "epoch":
            # first_episode=0 reuses the schedule the timed runs
            # compiled (run() restarts episode numbering each call)
            assert_epoch_dispatch_count(search, first_episode=0,
                                        n_batches=EPOCH_BATCHES)
            row["epoch_batches"] = EPOCH_BATCHES
            row["dispatches_per_epoch"] = 1
            row["speedup_vs_fused"] = round(eps[name] / eps["fused"], 2)
        rows.append(row)
    if verbose:
        print(f"[engine] K={batch_size} updates={updates}: "
              + ", ".join(f"{n} {eps[n]:.1f} eps/s"
                          for n in ("scalar", "batched", "fused",
                                    "epoch"))
              + f" -> epoch/fused {eps['epoch'] / eps['fused']:.2f}x",
              flush=True)
    return rows


def population_comparison(batch_size: int = 8, episodes: int = 32,
                          updates: int = 8, verbose: bool = True) -> dict:
    """Aggregate episodes/sec for the paper's p/q/pq agent trio:
    three independent batched searches vs one PopulationSearch whose
    members share each update dispatch via ``jit(vmap(update_chunk))``.

    Action dims are padded to the joint agent's 3 in both arms so the
    comparison isolates dispatch sharing, not network sizes.
    """
    methods = ("p", "q", "pq")
    warm, total = 16, episodes * len(methods)   # 2 chunks: see above

    def fresh(seed0):
        return [_tiny_engine(True, batch_size, updates, methods=m,
                             action_dim=3, seed=seed0 + i)
                for i, m in enumerate(methods)]

    import jax

    def fence(ms):      # async update chunks must land inside the timer
        for m in ms:
            jax.block_until_ready(m.agent.state)

    # --- independent: each member flushes its own fused update chunks
    members = fresh(0)
    for m in members:
        m.run(episodes=warm)             # warm the jit caches
    indep = 0.0
    for _ in range(3):                   # best-of-N (see episodes_per_sec)
        t0 = time.perf_counter()
        for m in members:
            m.run(episodes=episodes)
        fence(members)
        indep = max(indep, total / (time.perf_counter() - t0))

    # --- population: one vmapped update dispatch for all members
    pop = PopulationSearch(fresh(100))
    pop.run(episodes=warm)
    shared = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        pop.run(episodes=episodes)
        fence(pop.members)
        shared = max(shared, total / (time.perf_counter() - t0))

    out = {"table": "population", "members": list(methods),
           "batch_size": batch_size, "episodes_per_member": episodes,
           "updates_per_episode": updates,
           "independent_eps_per_s": round(indep, 2),
           "population_eps_per_s": round(shared, 2),
           "speedup": round(shared / indep, 2)}
    if verbose:
        print(f"[population] P={len(methods)} K={batch_size} "
              f"updates={updates}: independent {indep:.1f} eps/s, "
              f"shared-dispatch {shared:.1f} eps/s "
              f"-> {shared / indep:.2f}x", flush=True)
    return out


# ===========================================================================
# Update floor: vmap reference vs megabatched population chunks (ISSUE 7)
# ===========================================================================

def _paper_population(P: int, seed: int = 0):
    """P paper-sized agents ((400, 300) hidden, batch 128) with filled
    device replays — the exact update workload PopulationSearch
    dispatches."""
    import jax
    import numpy as np
    from repro.core.ddpg import agent_init, tree_stack
    from repro.core.replay import DeviceReplay
    cfg = DDPGConfig(state_dim=10, action_dim=3, batch_size=128,
                     buffer_size=2000)
    rng = np.random.default_rng(seed)
    states, replays = [], []
    for p in range(P):
        states.append(agent_init(cfg, jax.random.PRNGKey(seed + p)))
        rep = DeviceReplay(cfg.buffer_size, cfg.state_dim, cfg.action_dim)
        n = 600
        rep.push_batch(
            rng.standard_normal((n, cfg.state_dim)).astype(np.float32),
            rng.uniform(size=(n, cfg.action_dim)).astype(np.float32),
            rng.standard_normal(n).astype(np.float32),
            rng.standard_normal((n, cfg.state_dim)).astype(np.float32),
            rng.integers(0, 2, n).astype(np.float32))
        replays.append(rep.data)
    return cfg, tree_stack(states), tree_stack(replays)


def _print_update_gemm_shapes(cfg, P: int):
    """The GEMM shapes each path dispatches per scan step — so floor
    regressions are diagnosable from the benchmark log alone."""
    B = cfg.batch_size
    h1, h2 = cfg.hidden
    S, A = cfg.state_dim, cfg.action_dim
    critic = [(S + A, h1), (h1, h2), (h2, 1)]
    actor = [(S, h1), (h1, h2), (h2, A)]
    print(f"  [shapes] P={P} B={B}: per-layer GEMMs (fwd) "
          + " ".join(f"({P},{B},{i})x({P},{i},{o})"
                     for i, o in critic + actor)
          + f"; bwd dW einsum pbi,pbo->pio, dx einsum pbo,pio->pbi; "
          f"both paths batch over P (vmap via batched dot_general, "
          f"megabatch explicitly)", flush=True)


@contextmanager
def megabatch_dispatch_probe():
    """Compile-counter hook for the population update path: counts REAL
    invocations of the megabatched compiled entries (plain + donating)
    and plants canaries on the vmap population jit and the per-member
    update-chunk jit — a silent fallback to either is caught."""
    import repro.core.ddpg as ddpg_mod
    counts = {"mega": 0, "vmap": 0, "member": 0}
    names = {"_population_update_chunk_mega_jit": "mega",
             "_population_update_chunk_mega_donate_jit": "mega",
             "_population_update_chunk_jit": "vmap",
             "_update_chunk_jit": "member"}
    saved = {}

    def wrap(name, key):
        fn = getattr(ddpg_mod, name)
        saved[name] = fn

        def counting(*a, **kw):
            counts[key] += 1
            return fn(*a, **kw)

        setattr(ddpg_mod, name, counting)

    for name, key in names.items():
        wrap(name, key)
    try:
        yield counts
    finally:
        for name, fn in saved.items():
            setattr(ddpg_mod, name, fn)


def assert_megabatch_dispatch_count(cfg, states, replays, n: int) -> dict:
    """One routed population chunk must be exactly ONE execution of the
    megabatched compiled entry — never the vmap reference or P
    per-member chunks. Runs in the weekly job; a regression fails it."""
    from repro.core.ddpg import population_update_chunk
    population_update_chunk(cfg, states, replays, n)    # compile outside
    with megabatch_dispatch_probe() as counts:
        population_update_chunk(cfg, states, replays, n)
    assert counts["mega"] == 1, \
        f"population chunk made {counts['mega']} megabatch executions: " \
        f"{counts}"
    assert counts["vmap"] == 0 and counts["member"] == 0, \
        f"population chunk fell back off the megabatched path: {counts}"
    return counts


def update_floor_comparison(pops=(1, 4, 16), updates: int = 8,
                            repeats: int = 5, verbose: bool = True) -> list:
    """ms/update of the DDPG population chunk, vmap reference vs the
    megabatched path, at P member counts. Best-of-N interleaved
    round-robin (box drift hits both arms equally); the megabatched arm
    runs the production donating entry, so each rep feeds it a fresh
    copy of the stacked states (copies made OUTSIDE the timed region).

    ``ms_per_update`` is wall ms per scan step (all P members advance
    one update); ``ms_per_member_update`` divides by P."""
    import jax
    import jax.numpy as jnp
    from repro.core.ddpg import (population_update_chunk_megabatched,
                                 population_update_chunk_vmap)
    rows = []
    for P in pops:
        cfg, states, replays = _paper_population(P)
        if verbose:
            _print_update_gemm_shapes(cfg, P)
        copy = lambda: jax.tree.map(jnp.copy, states)
        arms = {
            "vmap": lambda s: population_update_chunk_vmap(
                cfg, s, replays, updates),
            "megabatch": lambda s: population_update_chunk_megabatched(
                cfg, s, replays, updates, donate=True),
        }
        for fn in arms.values():
            jax.block_until_ready(fn(copy())[0])        # warm the jits
        best = {name: float("inf") for name in arms}
        for _ in range(repeats):
            for name, fn in arms.items():
                s = copy()
                jax.block_until_ready(s)
                t0 = time.perf_counter()
                out, _ = fn(s)
                jax.block_until_ready(out)
                best[name] = min(best[name], time.perf_counter() - t0)
        counts = assert_megabatch_dispatch_count(
            cfg, copy(), replays, updates)
        for name in arms:
            ms = best[name] * 1000.0 / updates
            row = {"table": "update_floor", "engine": name, "members": P,
                   "batch_size": cfg.batch_size,
                   "updates_per_episode": updates,
                   "ms_per_update": round(ms, 3),
                   "ms_per_member_update": round(ms / P, 3)}
            if name == "megabatch":
                row["dispatches_per_chunk"] = counts["mega"]
                row["speedup_vs_vmap"] = round(
                    best["vmap"] / best["megabatch"], 3)
            rows.append(row)
        if verbose:
            print(f"[update_floor] P={P} n={updates}: "
                  f"vmap {best['vmap'] * 1000 / updates:.2f} ms/update, "
                  f"megabatch {best['megabatch'] * 1000 / updates:.2f} "
                  f"ms/update -> "
                  f"{best['vmap'] / best['megabatch']:.2f}x", flush=True)
    return rows


# ===========================================================================
# Fleet scaling: mesh-sharded population epochs, 1 vs 4 devices (ISSUE 8)
# ===========================================================================

FLEET_SCALING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import time
    import jax
    from benchmarks.search_setup import \\
        assert_population_epoch_dispatch_count
    from repro.launch.fleet import tiny_fleet

    P, E, EPISODES, REPS = 4, 2, 16, 5
    arms = {
        "fleet_1dev": tiny_fleet(members=P, data=0, updates=2, seed0=0),
        "fleet_4dev": tiny_fleet(members=P, data=4, updates=2, seed0=0),
    }
    # warm: the first chunk straddles the agent's warmup boundary, so
    # both the warmup-straddling and the steady epoch schedules compile
    # here, outside the timed region
    for f in arms.values():
        f.run_fleet(f.epoch_cursor + EPISODES)
    best = {n: 0.0 for n in arms}
    for _ in range(REPS):
        for n, f in arms.items():
            t0 = time.perf_counter()
            f.run_fleet(f.epoch_cursor + EPISODES)
            best[n] = max(best[n],
                          P * EPISODES / (time.perf_counter() - t0))
    probe = assert_population_epoch_dispatch_count(
        arms["fleet_4dev"], arms["fleet_4dev"].epoch_cursor, E)
    print(json.dumps({"eps": best, "devices": len(jax.devices()),
                      "pop_epoch": probe["pop_epoch"]}))
""")


def fleet_scaling_rows(verbose: bool = True) -> list:
    """Aggregate eps/s of a P=4 ``FleetSearch`` (updates>0) with the
    same workload pinned to one device vs sharded over a 4-device mesh,
    best-of-5 interleaved round-robin. Runs in a FRESH subprocess — the
    CPU device count locks at first jax init, so the forced-host-device
    recipe cannot run in the benchmark process itself.

    Honest-measurement note (the PR 7 precedent): on this 1-core CI box
    every forced host device shares the same core, so the 4-device arm
    measures ~1x the 1-device arm — the sharded program's win needs
    genuinely parallel devices. The rows pin the sharded dispatch path
    (the probe asserts the 1-execution bound) and its eps/s against
    regression; the >=2x multiple lives on real multi-device backends.
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", FLEET_SCALING_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1800, cwd=root)
    if res.returncode != 0:
        raise RuntimeError(
            f"fleet_scaling subprocess failed:\n{res.stderr[-3000:]}")
    out = json.loads(res.stdout.strip().splitlines()[-1])
    rows = []
    for name, devices in (("fleet_1dev", 1), ("fleet_4dev", 4)):
        row = {"table": "fleet_scaling", "engine": name, "members": 4,
               "batch_size": 4, "updates_per_episode": 2,
               "devices": devices,
               "eps_per_s": round(out["eps"][name], 2)}
        if name == "fleet_4dev":
            row["dispatches_per_epoch"] = out["pop_epoch"]
            row["speedup_vs_1dev"] = round(
                out["eps"]["fleet_4dev"] / out["eps"]["fleet_1dev"], 2)
        rows.append(row)
    if verbose:
        print(f"[fleet_scaling] P=4 K=4 updates=2: "
              f"1dev {out['eps']['fleet_1dev']:.1f} eps/s, "
              f"4dev {out['eps']['fleet_4dev']:.1f} eps/s -> "
              f"{out['eps']['fleet_4dev'] / out['eps']['fleet_1dev']:.2f}x "
              f"(forced host devices share this box's single core)",
              flush=True)
    return rows


# ===========================================================================
# Serving throughput of the deployed compressed model (ISSUE 7)
# ===========================================================================

def serve_throughput_rows(batch: int = 4, steps: int = 32,
                          requests: int = 4, verbose: bool = True) -> list:
    """tokens/s the deployed tiny LM sustains under back-to-back batched
    decode requests, for uniform INT8 and INT4-weight policies — the
    end-to-end number the whole compression pipeline is for. Gated
    weekly (``serve_tok_per_s``, higher is better)."""
    from repro.core.policy import Policy
    from repro.core.spec import LayerCMP
    from repro.launch.serve import sustained_throughput
    cm, _ = _tiny_testbed()
    cfg = cm.cfg
    policies = {
        "serve_int8": Policy([LayerCMP(keep=s.prune_dim, mode="INT8",
                                       w_bits=8, a_bits=8)
                              for s in cm.specs]),
        "serve_int4": Policy([LayerCMP(keep=s.prune_dim, mode="MIX",
                                       w_bits=4, a_bits=8)
                              for s in cm.specs]),
    }
    rows = []
    for name, pol in policies.items():
        cspec = cm.build_cspec(pol)
        tok_s, times = sustained_throughput(
            cfg, cm.params, batch, steps, max_len=steps + 8, cspec=cspec,
            requests=requests)
        rows.append({"table": "serve", "engine": name,
                     "batch_size": batch, "steps": steps,
                     "requests": requests,
                     "serve_tok_per_s": round(tok_s, 1)})
        if verbose:
            print(f"[serve] {name}: {requests} requests x {batch}x{steps} "
                  f"tokens -> {tok_s:.1f} tok/s "
                  f"(per-request {min(times):.3f}-{max(times):.3f}s)",
                  flush=True)
    return rows


def main(out: str = "artifacts/bench_engine.json"):
    rows = (engine_comparison(updates=0) + engine_comparison(updates=8)
            + [calibrated_fused_row(), population_comparison()]
            + sensitivity_comparison()
            + update_floor_comparison()
            + serve_throughput_rows()
            + fleet_scaling_rows())
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out}", flush=True)
    return rows


if __name__ == "__main__":
    main()
