"""Search construction shared by the paper-table benchmarks, plus the
scalar-vs-batched episode-engine throughput comparison
(``python -m benchmarks.search_setup`` prints episodes/sec for both)."""
from __future__ import annotations

import os
import time

from benchmarks.common import IMG_CTX, SERVE_CTX, get_lm_testbed, \
    get_resnet_testbed
from repro.core.compress import CompressibleLM, CompressibleResNet
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import (BatchedCompressionSearch, CompressionSearch,
                               SearchConfig)
from repro.core.sensitivity import run_sensitivity

FULL = os.environ.get("GALEN_BENCH_FULL", "0") == "1"

# paper: 310 (quant) / 410 (prune, joint) episodes, 10 warm-up.
EPISODES = {"p": 410, "q": 310, "pq": 410} if FULL else \
    {"p": 60, "q": 50, "pq": 60}
WARMUP = 10
UPDATES = 48 if FULL else 24

_sens_cache = {}


def lm_search(methods: str, c: float, seed: int = 0, episodes=None,
              sens_enabled: bool = True, cls=CompressionSearch,
              **cls_kw) -> CompressionSearch:
    cfg, params, val, acc = get_lm_testbed()
    # smaller eval batch: ~2x faster episodes, ±2% accuracy noise (the
    # paper also validates on a small split during search)
    val = {k: v[:32] for k, v in val.items()}
    cm = CompressibleLM(cfg, params)
    key = ("lm", sens_enabled)
    if key not in _sens_cache:
        if sens_enabled:
            _sens_cache[key] = run_sensitivity(cm, val)
        else:
            from repro.core.sensitivity import SensitivityResult
            _sens_cache[key] = SensitivityResult(
                {s.name: {} for s in cm.specs})  # constant features
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000),
        seed=seed)
    return cls(cm, val, scfg, SERVE_CTX, sens=_sens_cache[key], **cls_kw)


def lm_batched_search(methods: str, c: float, seed: int = 0, episodes=None,
                      sens_enabled: bool = True,
                      batch_size: int = 8) -> BatchedCompressionSearch:
    """lm_search with the batched episode engine (K episodes/rollout)."""
    return lm_search(methods, c, seed=seed, episodes=episodes,
                     sens_enabled=sens_enabled,
                     cls=BatchedCompressionSearch, batch_size=batch_size)


def resnet_search(methods: str, c: float, seed: int = 0,
                  episodes=None) -> CompressionSearch:
    rcfg, params, val, acc = get_resnet_testbed()
    cm = CompressibleResNet(rcfg, params)
    if "resnet" not in _sens_cache:
        _sens_cache["resnet"] = run_sensitivity(cm, val)
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000),
        seed=seed)
    return CompressionSearch(cm, val, scfg, IMG_CTX,
                             sens=_sens_cache["resnet"])


# ===========================================================================
# Episode-engine throughput: scalar loop vs batched rollout
# ===========================================================================

def _tiny_engine(batched: bool, batch_size: int, updates: int):
    """Search on a tiny untrained LM — engine overhead dominates, which
    is exactly what this comparison isolates."""
    import jax
    from repro.configs.base import ArchConfig
    from repro.data.pipeline import bigram_lm
    from repro.models import model as M

    cfg = ArchConfig(name="tiny-engine", num_layers=3, d_model=64,
                     num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256,
                     vocab_size=128, scan_layers=True)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
    cm = CompressibleLM(cfg, params)
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods="pq", episodes=64, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=4, updates_per_episode=updates,
                        batch_size=16, buffer_size=512))
    if batched:
        return BatchedCompressionSearch(cm, batch, scfg, ctx,
                                        batch_size=batch_size)
    return CompressionSearch(cm, batch, scfg, ctx)


def episodes_per_sec(search, episodes: int = 32,
                     warmup_episodes: int = 8) -> float:
    search.run(episodes=warmup_episodes)     # warm the jit caches
    t0 = time.perf_counter()
    search.run(episodes=episodes)
    return episodes / (time.perf_counter() - t0)


def engine_comparison(batch_size: int = 8, episodes: int = 32,
                      updates: int = 0, verbose: bool = True) -> dict:
    """Episodes/sec, scalar vs batched, on the tiny LM.

    ``updates=0`` isolates rollout+validation throughput (the part the
    batched engine amortizes); agent updates cost the same per episode
    on both paths and dilute the ratio.
    """
    scalar = episodes_per_sec(_tiny_engine(False, batch_size, updates),
                              episodes)
    batched = episodes_per_sec(_tiny_engine(True, batch_size, updates),
                               episodes)
    out = {"table": "engine", "batch_size": batch_size,
           "episodes": episodes, "updates_per_episode": updates,
           "scalar_eps_per_s": round(scalar, 2),
           "batched_eps_per_s": round(batched, 2),
           "speedup": round(batched / scalar, 2)}
    if verbose:
        print(f"[engine] K={batch_size} updates={updates}: "
              f"scalar {scalar:.1f} eps/s, batched {batched:.1f} eps/s "
              f"-> {batched / scalar:.2f}x", flush=True)
    return out


if __name__ == "__main__":
    engine_comparison(updates=0)
    engine_comparison(updates=8)
