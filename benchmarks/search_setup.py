"""Search construction shared by the paper-table benchmarks, plus the
episode-engine throughput comparisons: scalar vs batched rollouts, and
independent vs population-shared (vmapped) agent updates.

``python -m benchmarks.search_setup`` prints episodes/sec for all of
them and writes the rows to ``artifacts/bench_engine.json`` (uploaded
weekly by CI so update-path regressions are visible)."""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

from benchmarks.common import IMG_CTX, SERVE_CTX, get_lm_testbed, \
    get_resnet_testbed
from repro.core.compress import CompressibleLM, CompressibleResNet
from repro.core.ddpg import DDPGConfig
from repro.core.latency import LatencyContext
from repro.core.reward import RewardConfig
from repro.core.search import (BatchedCompressionSearch, CompressionSearch,
                               FusedCompressionSearch, PopulationSearch,
                               SearchConfig)
from repro.core.sensitivity import run_sensitivity

ENGINES = {"scalar": CompressionSearch, "batched": BatchedCompressionSearch,
           "fused": FusedCompressionSearch}

FULL = os.environ.get("GALEN_BENCH_FULL", "0") == "1"

# paper: 310 (quant) / 410 (prune, joint) episodes, 10 warm-up.
EPISODES = {"p": 410, "q": 310, "pq": 410} if FULL else \
    {"p": 60, "q": 50, "pq": 60}
WARMUP = 10
UPDATES = 48 if FULL else 24

_sens_cache = {}


def lm_search(methods: str, c: float, seed: int = 0, episodes=None,
              sens_enabled: bool = True, cls=CompressionSearch,
              action_dim: int = 0, **cls_kw) -> CompressionSearch:
    """``action_dim`` > the method's native count pads the agent's
    action space (required for mixed-method PopulationSearch members)."""
    cfg, params, val, acc = get_lm_testbed()
    # smaller eval batch: ~2x faster episodes, ±2% accuracy noise (the
    # paper also validates on a small split during search)
    val = {k: v[:32] for k, v in val.items()}
    cm = CompressibleLM(cfg, params)
    key = ("lm", sens_enabled)
    if key not in _sens_cache:
        if sens_enabled:
            _sens_cache[key] = run_sensitivity(cm, val)
        else:
            from repro.core.sensitivity import SensitivityResult
            _sens_cache[key] = SensitivityResult(
                {s.name: {} for s in cm.specs})  # constant features
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000,
                        action_dim=action_dim or 1),
        seed=seed)
    return cls(cm, val, scfg, SERVE_CTX, sens=_sens_cache[key], **cls_kw)


def lm_batched_search(methods: str, c: float, seed: int = 0, episodes=None,
                      sens_enabled: bool = True,
                      batch_size: int = 8) -> BatchedCompressionSearch:
    """lm_search with the batched episode engine (K episodes/rollout)."""
    return lm_search(methods, c, seed=seed, episodes=episodes,
                     sens_enabled=sens_enabled,
                     cls=BatchedCompressionSearch, batch_size=batch_size)


def lm_fused_search(methods: str, c: float, seed: int = 0, episodes=None,
                    sens_enabled: bool = True,
                    batch_size: int = 8) -> FusedCompressionSearch:
    """lm_search with the fused engine (whole rollout = one dispatch)."""
    return lm_search(methods, c, seed=seed, episodes=episodes,
                     sens_enabled=sens_enabled,
                     cls=FusedCompressionSearch, batch_size=batch_size)


def resnet_search(methods: str, c: float, seed: int = 0,
                  episodes=None) -> CompressionSearch:
    rcfg, params, val, acc = get_resnet_testbed()
    cm = CompressibleResNet(rcfg, params)
    if "resnet" not in _sens_cache:
        _sens_cache["resnet"] = run_sensitivity(cm, val)
    scfg = SearchConfig(
        methods=methods,
        episodes=episodes or EPISODES[methods],
        reward=RewardConfig(target_ratio=c, beta=-3.0),
        ddpg=DDPGConfig(warmup_episodes=WARMUP, updates_per_episode=UPDATES,
                        batch_size=128, buffer_size=2000),
        seed=seed)
    return CompressionSearch(cm, val, scfg, IMG_CTX,
                             sens=_sens_cache["resnet"])


# ===========================================================================
# Episode-engine throughput: scalar loop vs batched rollout
# ===========================================================================

_tiny_testbed_cache = {}


def _tiny_testbed():
    """Tiny untrained LM + shared sensitivity — engine overhead
    dominates its episodes, which is what these comparisons isolate."""
    if "lm" not in _tiny_testbed_cache:
        import jax
        from repro.configs.base import ArchConfig
        from repro.data.pipeline import bigram_lm
        from repro.models import model as M

        cfg = ArchConfig(name="tiny-engine", num_layers=3, d_model=64,
                         num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256,
                         vocab_size=128, scan_layers=True)
        params = M.init(cfg, jax.random.PRNGKey(0))
        batch = bigram_lm(cfg.vocab_size, 8, 32, seed=3)
        _tiny_testbed_cache["lm"] = (CompressibleLM(cfg, params), batch)
    return _tiny_testbed_cache["lm"]


def _tiny_engine(engine, batch_size: int, updates: int,
                 methods: str = "pq", action_dim: int = 0, seed: int = 0):
    """``engine``: "scalar" | "batched" | "fused" (bools kept for the
    original scalar/batched call sites)."""
    if isinstance(engine, bool):
        engine = "batched" if engine else "scalar"
    cm, batch = _tiny_testbed()
    ctx = LatencyContext(tokens=1, seq_ctx=256, mode="decode", batch=1)
    scfg = SearchConfig(
        methods=methods, episodes=64, reward=RewardConfig(target_ratio=0.5),
        ddpg=DDPGConfig(warmup_episodes=4, updates_per_episode=updates,
                        batch_size=16, buffer_size=512,
                        action_dim=action_dim or 1),
        seed=seed)
    cls = ENGINES[engine]
    if engine == "scalar":
        return cls(cm, batch, scfg, ctx)
    return cls(cm, batch, scfg, ctx, batch_size=batch_size)


def episodes_per_sec(search, episodes: int = 32,
                     warmup_episodes: int = 16, repeats: int = 3) -> float:
    # warm the jit caches over TWO chunks: the first chunk straddles the
    # agent's warmup boundary, so its fused update chunk is shorter than
    # the steady-state one — both scan lengths must compile here, not in
    # the timed region
    search.run(episodes=warmup_episodes)
    # best-of-N: shared CI/dev boxes show ±20% run-to-run contention
    # noise, and the minimum is the stable estimate of engine cost
    import jax
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        search.run(episodes=episodes)
        # the final fused update chunk is dispatched asynchronously —
        # fence it so the timed region contains all of its work
        jax.block_until_ready(search.agent.state)
        best = min(best, time.perf_counter() - t0)
    return episodes / best


@contextmanager
def fused_dispatch_probe(search):
    """Compile-counter hook: counts REAL invocations of the fused
    path's compiled entry points (rollout jit, fused validation jit,
    replay ring-write jit, update-chunk jit) by wrapping the callables
    themselves — not trusting the engine's own ``dispatch_log`` — and
    plants canaries on the per-step host path (``act_batch``, the numpy
    batch oracle) so a regression that silently falls back to L host
    steps per batch is caught even though it makes no jit calls."""
    import repro.core.ddpg as ddpg_mod
    import repro.core.replay as replay_mod
    import repro.core.search as search_mod
    counts = {"rollout": 0, "validate": 0, "push": 0, "update": 0,
              "host_steps": 0}
    saved = []

    def wrap(obj, name, key):
        fn = getattr(obj, name)
        saved.append((obj, name, name in vars(obj), fn))

        def counting(*a, **kw):
            counts[key] += 1
            return fn(*a, **kw)

        setattr(obj, name, counting)

    wrap(search, "_rollout", "rollout")
    wrap(search.cmodel, "accuracy_policy_batch", "validate")
    wrap(replay_mod, "_device_push", "push")
    wrap(ddpg_mod, "_update_chunk_jit", "update")
    # canaries — the numpy engines' per-unit-step host machinery
    wrap(search.agent, "act_batch", "host_steps")
    wrap(search_mod, "policy_latency_batch", "host_steps")
    try:
        yield counts
    finally:
        for obj, name, was_own, fn in reversed(saved):
            if was_own:
                setattr(obj, name, fn)
            else:
                delattr(obj, name)


def assert_fused_dispatch_count(search, first_episode: int,
                                batch_size: int) -> dict:
    """One post-compile episode batch on the fused engine must stay
    within the ISSUE 3 bound: rollout + validation + ring write +
    update chunk <= 4 jit executions, zero per-step host work. Also
    checks the engine's ``dispatch_log`` agrees with the measured
    counts. Runs in the weekly job; a regression fails it."""
    search.dispatch_log.clear()
    with fused_dispatch_probe(search) as counts:
        search.run_episode_batch(first_episode, batch_size)
        search._flush_updates()
    total = sum(counts[k] for k in ("rollout", "validate", "push",
                                    "update"))
    assert counts["host_steps"] == 0, \
        f"per-step host path ran under the fused engine: {counts}"
    assert total <= 4, f"fused engine made {total} dispatches: {counts}"
    assert len(search.dispatch_log) == total, \
        f"dispatch_log {search.dispatch_log} != measured {counts}"
    return counts


def engine_comparison(batch_size: int = 8, episodes: int = 32,
                      updates: int = 0, verbose: bool = True) -> dict:
    """Episodes/sec, scalar vs batched vs fused, on the tiny LM.

    ``updates=0`` isolates rollout+validation throughput — where the
    fused engine's one-dispatch rollout pays off most; with updates
    enabled every engine dispatches each episode batch's updates as one
    fused ``update_chunk`` scan (PR 2), so the rollout engines amortize
    rollout AND learning dispatch.
    """
    scalar = episodes_per_sec(_tiny_engine("scalar", batch_size, updates),
                              episodes)
    batched = episodes_per_sec(_tiny_engine("batched", batch_size, updates),
                               episodes)
    fused_search = _tiny_engine("fused", batch_size, updates)
    fused = episodes_per_sec(fused_search, episodes)
    counts = assert_fused_dispatch_count(
        fused_search, first_episode=64, batch_size=batch_size)
    n_disp = sum(counts[k] for k in ("rollout", "validate", "push",
                                     "update"))
    out = {"table": "engine", "batch_size": batch_size,
           "episodes": episodes, "updates_per_episode": updates,
           "scalar_eps_per_s": round(scalar, 2),
           "batched_eps_per_s": round(batched, 2),
           "fused_eps_per_s": round(fused, 2),
           "speedup": round(batched / scalar, 2),
           "fused_speedup_vs_batched": round(fused / batched, 2),
           "fused_dispatches_per_batch": n_disp}
    if verbose:
        print(f"[engine] K={batch_size} updates={updates}: "
              f"scalar {scalar:.1f} eps/s, batched {batched:.1f} eps/s, "
              f"fused {fused:.1f} eps/s ({n_disp} dispatches/batch) "
              f"-> fused/batched {fused / batched:.2f}x", flush=True)
    return out


def population_comparison(batch_size: int = 8, episodes: int = 32,
                          updates: int = 8, verbose: bool = True) -> dict:
    """Aggregate episodes/sec for the paper's p/q/pq agent trio:
    three independent batched searches vs one PopulationSearch whose
    members share each update dispatch via ``jit(vmap(update_chunk))``.

    Action dims are padded to the joint agent's 3 in both arms so the
    comparison isolates dispatch sharing, not network sizes.
    """
    methods = ("p", "q", "pq")
    warm, total = 16, episodes * len(methods)   # 2 chunks: see above

    def fresh(seed0):
        return [_tiny_engine(True, batch_size, updates, methods=m,
                             action_dim=3, seed=seed0 + i)
                for i, m in enumerate(methods)]

    import jax

    def fence(ms):      # async update chunks must land inside the timer
        for m in ms:
            jax.block_until_ready(m.agent.state)

    # --- independent: each member flushes its own fused update chunks
    members = fresh(0)
    for m in members:
        m.run(episodes=warm)             # warm the jit caches
    indep = 0.0
    for _ in range(3):                   # best-of-N (see episodes_per_sec)
        t0 = time.perf_counter()
        for m in members:
            m.run(episodes=episodes)
        fence(members)
        indep = max(indep, total / (time.perf_counter() - t0))

    # --- population: one vmapped update dispatch for all members
    pop = PopulationSearch(fresh(100))
    pop.run(episodes=warm)
    shared = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        pop.run(episodes=episodes)
        fence(pop.members)
        shared = max(shared, total / (time.perf_counter() - t0))

    out = {"table": "population", "members": list(methods),
           "batch_size": batch_size, "episodes_per_member": episodes,
           "updates_per_episode": updates,
           "independent_eps_per_s": round(indep, 2),
           "population_eps_per_s": round(shared, 2),
           "speedup": round(shared / indep, 2)}
    if verbose:
        print(f"[population] P={len(methods)} K={batch_size} "
              f"updates={updates}: independent {indep:.1f} eps/s, "
              f"shared-dispatch {shared:.1f} eps/s "
              f"-> {shared / indep:.2f}x", flush=True)
    return out


def main(out: str = "artifacts/bench_engine.json"):
    rows = [engine_comparison(updates=0),
            engine_comparison(updates=8),
            population_comparison()]
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out}", flush=True)
    return rows


if __name__ == "__main__":
    main()
