"""Paper Table 1 on the paper's own model family: ResNet (conv channel
pruning + quantization) on the blob-image task — per-image latency on one
v5e chip as the device, mirroring the Raspberry-Pi single-image scenario.

  PYTHONPATH=src:. python -m benchmarks.resnet_table1
"""
from __future__ import annotations

import json
import os

from benchmarks.search_setup import resnet_search


def run(cs=(0.5, 0.35), verbose=True):
    rows = []
    for c in cs:
        for methods, label in (("p", "Pruning Agent"),
                               ("q", "Quantization A."),
                               ("pq", "Joint Agent")):
            search = resnet_search(methods, c, seed=11)
            res = search.run(verbose=False)
            best = res.best_under_budget(0.05) or res.best
            rows.append({
                "table": "resnet_table1", "method": label, "c": c,
                "macs_frac": round(best.macs_frac, 4),
                "latency_frac": round(best.latency_s / res.ref_latency_s, 4),
                "on_budget": bool(best.latency_ratio <= 1.05),
                "accuracy": round(best.accuracy, 4),
                "ref_accuracy": round(res.ref_accuracy, 4),
            })
            if verbose:
                r = rows[-1]
                print(f"[resnet-t1] {label:16s} c={c}: "
                      f"lat={r['latency_frac']:.3f} acc={r['accuracy']:.3f} "
                      f"(clean {r['ref_accuracy']:.3f}) "
                      f"macs={r['macs_frac']:.3f} budget={r['on_budget']}",
                      flush=True)
    return rows


def main(out="artifacts/bench_resnet_table1.json"):
    rows = run()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
