"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract,
then emits each table's rows. Fast subset by default; set
``GALEN_BENCH_FULL=1`` for paper-scale episode counts and the complete
sweeps (hours on one CPU core).

  table1  — agent comparison (paper Table 1)
  fig4    — target-rate sweep (paper Fig. 4)
  fig3    — policy analysis (paper Fig. 3)
  table2  — sensitivity ablation (paper Tab. 2 / Fig. 6-7)
  fig5    — sequential vs joint (paper App. A)         [FULL only]
  roofline— §Roofline table from the dry-run artifacts
  kernels — Pallas kernel micro-bench (CPU interpret)
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

FULL = os.environ.get("GALEN_BENCH_FULL", "0") == "1"


def _stage(name, fn):
    t0 = time.time()
    out = fn()
    us = (time.time() - t0) * 1e6
    n = len(out) if hasattr(out, "__len__") else 1
    print(f"{name},{us:.0f},rows={n}", flush=True)
    return out


def main() -> None:
    from benchmarks import (agent_comparison, kernel_bench, policy_analysis,
                            rate_sweep, roofline, sensitivity_ablation)

    print("name,us_per_call,derived")
    _stage("bench.kernels", lambda: kernel_bench.run(verbose=True))
    _stage("bench.table1_agent_comparison", lambda: agent_comparison.main())
    _stage("bench.fig4_rate_sweep", lambda: rate_sweep.main())
    _stage("bench.fig3_policy_analysis", lambda: policy_analysis.main())
    _stage("bench.table2_sensitivity", lambda: sensitivity_ablation.main())
    if FULL:
        from benchmarks import resnet_table1, sequential_vs_joint
        _stage("bench.fig5_sequential_vs_joint",
               lambda: sequential_vs_joint.main())
        _stage("bench.resnet_table1", lambda: resnet_table1.main())
    _stage("bench.roofline", lambda: roofline.main(verbose=True))
    print("bench.done,0,ok")


if __name__ == "__main__":
    main()
