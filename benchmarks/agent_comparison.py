"""Paper Table 1: compressed-model performance per agent at target
compression ratios c (pruning / quantization / joint).

Reports MACs fraction, BOPs, oracle latency ratio, accuracy before and
after a short QAT retrain (the paper retrains 30 epochs)."""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.search_setup import lm_search
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step


def qat_retrain(search, policy, steps: int = 60):
    """Short QAT retrain of the compressed model (paper: 30 epochs)."""
    cm = search.cmodel
    cs = cm.build_cspec(policy)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=steps,
                           weight_decay=0.0)
    params = cm.params
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(cm.cfg, ocfg, cspec=cs))
    from repro.data.pipeline import make_bigram_table, sample_bigram
    import jax.numpy as jnp
    table = make_bigram_table(cm.cfg.vocab_size, 0)
    for s in range(steps):
        toks = sample_bigram(table, 16, 48, 777_000 + s)
        params, opt, _ = step(params, opt, {"tokens": jnp.asarray(toks)})
    # evaluate retrained accuracy with the SAME policy cspec
    retrained = type(cm)(cm.cfg, params)
    cs2 = retrained.build_cspec(policy)
    return float(retrained.accuracy(search.val_batch, cs2))


def run(cs=(0.5, 0.35), retrain: bool = True, verbose: bool = True):
    rows = []
    for c in cs:
        for methods, label in (("p", "Pruning Agent"),
                               ("q", "Quantization A."),
                               ("pq", "Joint Agent")):
            t0 = time.time()
            search = lm_search(methods, c, seed=1)
            res = search.run(verbose=False)
            best = res.best_under_budget(0.05) or res.best
            acc_rt = qat_retrain(search, best.policy) if retrain else None
            rows.append({
                "table": "table1", "method": label, "c": c,
                "macs_frac": round(best.macs_frac, 4),
                "bops": best.bops,
                "latency_ratio_vs_ref": round(
                    best.latency_s / res.ref_latency_s, 4),
                "latency_vs_target": round(best.latency_ratio, 4),
                "accuracy": round(best.accuracy, 4),
                "accuracy_retrained": (round(acc_rt, 4)
                                       if acc_rt is not None else None),
                "ref_accuracy": round(res.ref_accuracy, 4),
                "episodes": len(res.history),
                "search_s": round(time.time() - t0, 1),
            })
            if verbose:
                r = rows[-1]
                print(f"[table1] {label:16s} c={c}: lat/ref="
                      f"{r['latency_ratio_vs_ref']:.3f} acc={r['accuracy']:.3f}"
                      f" (retrained {r['accuracy_retrained']}) macs="
                      f"{r['macs_frac']:.3f}", flush=True)
    return rows


def main(out="artifacts/bench_table1.json",
         engine_out="artifacts/bench_engine.json"):
    rows = run()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    # scalar-vs-batched episode-engine throughput (own schema/artifact)
    from benchmarks.search_setup import engine_comparison
    with open(engine_out, "w") as f:
        json.dump([engine_comparison()], f, indent=1)
    return rows


if __name__ == "__main__":
    main()
