"""Paper Table 1: compressed-model performance per agent at target
compression ratios c (pruning / quantization / joint).

Reports MACs fraction, BOPs, oracle latency ratio, accuracy before and
after a short QAT retrain (the paper retrains 30 epochs).

``engine`` picks how the three agents are searched: "scalar" (the
reference loop, default), or "population" — batched rollouts with the
p/q/pq agents sharing every update dispatch through one
``jit(vmap(update_chunk))`` (``PopulationSearch``; action dims padded
to the joint agent's 3)."""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.search_setup import lm_search
from repro.core.search import BatchedCompressionSearch, PopulationSearch
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step


def qat_retrain(search, policy, steps: int = 60):
    """Short QAT retrain of the compressed model (paper: 30 epochs)."""
    cm = search.cmodel
    cs = cm.build_cspec(policy)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=steps,
                           weight_decay=0.0)
    params = cm.params
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(cm.cfg, ocfg, cspec=cs))
    from repro.data.pipeline import make_bigram_table, sample_bigram
    import jax.numpy as jnp
    table = make_bigram_table(cm.cfg.vocab_size, 0)
    for s in range(steps):
        toks = sample_bigram(table, 16, 48, 777_000 + s)
        params, opt, _ = step(params, opt, {"tokens": jnp.asarray(toks)})
    # evaluate retrained accuracy with the SAME policy cspec
    retrained = type(cm)(cm.cfg, params)
    cs2 = retrained.build_cspec(policy)
    return float(retrained.accuracy(search.val_batch, cs2))


AGENTS = (("p", "Pruning Agent"), ("q", "Quantization A."),
          ("pq", "Joint Agent"))


def _search_trio(c, engine: str):
    """(search, result, elapsed_s) per agent, under the chosen engine."""
    if engine == "scalar":
        out = []
        for methods, _label in AGENTS:
            t0 = time.time()
            search = lm_search(methods, c, seed=1)
            res = search.run(verbose=False)
            out.append((search, res, time.time() - t0))
        return out
    if engine == "population":
        # members share one episode count (PopulationSearch runs the
        # population in lockstep); use the trio's maximum so no agent
        # gets a smaller search budget than under the scalar engine
        from benchmarks.search_setup import EPISODES
        episodes = max(EPISODES[m] for m, _label in AGENTS)
        searches = [lm_search(m, c, seed=1, cls=BatchedCompressionSearch,
                              episodes=episodes, action_dim=3, batch_size=8)
                    for m, _label in AGENTS]
        t0 = time.time()
        results = PopulationSearch(searches).run(episodes=episodes)
        dt = (time.time() - t0) / len(searches)
        return [(s, r, dt) for s, r in zip(searches, results)]
    raise ValueError(engine)


def run(cs=(0.5, 0.35), retrain: bool = True, verbose: bool = True,
        engine: str = "scalar"):
    rows = []
    for c in cs:
        trio = _search_trio(c, engine)
        for (methods, label), (search, res, dt) in zip(AGENTS, trio):
            best = res.best_under_budget(0.05) or res.best
            acc_rt = qat_retrain(search, best.policy) if retrain else None
            rows.append({
                "table": "table1", "method": label, "c": c,
                "macs_frac": round(best.macs_frac, 4),
                "bops": best.bops,
                "latency_ratio_vs_ref": round(
                    best.latency_s / res.ref_latency_s, 4),
                "latency_vs_target": round(best.latency_ratio, 4),
                "accuracy": round(best.accuracy, 4),
                "accuracy_retrained": (round(acc_rt, 4)
                                       if acc_rt is not None else None),
                "ref_accuracy": round(res.ref_accuracy, 4),
                "episodes": len(res.history),
                "engine": engine,
                "search_s": round(dt, 1),
            })
            if verbose:
                r = rows[-1]
                print(f"[table1] {label:16s} c={c}: lat/ref="
                      f"{r['latency_ratio_vs_ref']:.3f} acc={r['accuracy']:.3f}"
                      f" (retrained {r['accuracy_retrained']}) macs="
                      f"{r['macs_frac']:.3f}", flush=True)
    return rows


def main(out="artifacts/bench_table1.json",
         engine_out="artifacts/bench_engine.json"):
    rows = run()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    # engine throughput rows (scalar-vs-batched + population; own schema)
    from benchmarks.search_setup import main as engine_main
    engine_main(out=engine_out)
    return rows


if __name__ == "__main__":
    main()
