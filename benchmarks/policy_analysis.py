"""Paper Fig. 3: per-layer policies found by the three agents (text bars)."""
from __future__ import annotations

import json
import os

from benchmarks.search_setup import lm_search


def render_policy(specs, policy, width: int = 24) -> list[str]:
    lines = []
    for s, c in zip(specs, policy.cmps):
        if s.prunable and s.prune_dim:
            frac = c.keep / s.prune_dim
            bar = "#" * int(frac * width)
            lines.append(f"{s.name:16s} keep={c.keep:5d}/{s.prune_dim:<5d} "
                         f"|{bar:<{width}s}| {c.mode:4s} "
                         f"w{c.w_bits:<2d} a{c.a_bits:<2d}")
        elif s.quantizable:
            lines.append(f"{s.name:16s} {'':34s} {c.mode:4s} "
                         f"w{c.w_bits:<2d} a{c.a_bits:<2d}")
    return lines


def run(c=0.5, verbose=True):
    out = {}
    for m, label in (("p", "pruning"), ("q", "quantization"),
                     ("pq", "joint")):
        search = lm_search(m, c, seed=7)
        res = search.run(verbose=False)
        best = res.best_under_budget(0.05) or res.best
        lines = render_policy(search.specs, best.policy)
        out[label] = {
            "policy_render": lines,
            "accuracy": round(best.accuracy, 4),
            "latency_frac": round(best.latency_s / res.ref_latency_s, 4),
        }
        if verbose:
            print(f"\n[fig3] {label} agent (c={c}) acc={best.accuracy:.3f} "
                  f"lat={out[label]['latency_frac']:.3f}")
            for ln in lines:
                print("   " + ln)
    return out


def main(out="artifacts/bench_fig3.json"):
    rows = run()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
