"""§Perf hillclimbing driver: lower chosen (arch × shape) cells under
optimization variants and report the roofline-term deltas.

Run in a fresh process (512 host devices):
  PYTHONPATH=src:. python benchmarks/perf_variants.py --cell qwen2_decode
Outputs artifacts/perf_<cell>.json with one row per variant.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.launch.dryrun as DR
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_config


def lower_variant(arch, shape_name, mesh, *, deploy_bits=None, cache_bits=16,
                  overrides=None, label=""):
    cfg = get_config(arch)
    from repro.configs.base import SHAPES_BY_NAME
    shape = SHAPES_BY_NAME[shape_name]
    if shape.mode == "train":
        cfg = cfg.replace(remat="full")
    if overrides:
        cfg = cfg.replace(**overrides)
    scanned = cfg.scan_layers and cfg.homogeneous
    if scanned:
        # probe extrapolation (see dryrun): 1- and 2-layer unrolled compiles
        from repro.launch.inputs import model_flops
        r1, _ = DR._lower(cfg.replace(num_layers=1, scan_layers=False),
                          shape, mesh, deploy_bits=deploy_bits,
                          cache_bits=cache_bits)
        r2, _ = DR._lower(cfg.replace(num_layers=2, scan_layers=False),
                          shape, mesh, deploy_bits=deploy_bits,
                          cache_bits=cache_bits)
        row = DR._recombine(r1, r1, r2, cfg.num_layers, DR.V5E,
                            model_flops(cfg, shape), r1["chips"])
    else:
        row, _ = DR._lower(cfg, shape, mesh, deploy_bits=deploy_bits,
                           cache_bits=cache_bits)
    row["variant"] = label
    row["arch"], row["shape"] = arch, shape_name
    keep = ("variant", "arch", "shape", "chips", "flops", "bytes",
            "collective_bytes", "compute_s", "memory_s", "collective_s",
            "dominant", "step_s", "model_flops", "useful_flops_ratio",
            "roofline_fraction", "per_collective")
    return {k: row[k] for k in keep if k in row}


CELLS = {
    # Cell C (paper-representative): weight-memory-bound single-stream-ish
    # decode; the Galen policy attacks exactly this term.
    "qwen2_decode": [
        ("baseline_bf16", dict()),
        ("paper_int8_weights", dict(deploy_bits=8)),
        ("int4_weights", dict(deploy_bits=4)),
        ("int4_weights+int8_cache", dict(deploy_bits=4, cache_bits=8)),
        ("int4+cache8+pruned25", dict(deploy_bits=4, cache_bits=8,
                                      overrides={"d_ff": 3712})),
    ],
    # Cell B (worst roofline fraction): MHA (kv=36) long-context decode —
    # cache is length-sharded (36 heads don't divide the model axis).
    "minicpm_decode": [
        ("baseline_bf16", dict()),
        ("paper_int8_weights", dict(deploy_bits=8)),
        ("int8_weights+int8_cache", dict(deploy_bits=8, cache_bits=8)),
        ("int4_weights+int8_cache", dict(deploy_bits=4, cache_bits=8)),
        # B3: reshape the serving mesh so kv=36 divides the model axis ->
        # head-sharded cache, local DUS writes (36 % 4 == 0)
        ("B3_mesh64x4+int8_cache", dict(deploy_bits=8, cache_bits=8,
                                        mesh=(64, 4))),
    ],
    "granite_decode": [
        ("baseline_bf16", dict()),
        ("paper_int8_weights", dict(deploy_bits=8)),
        ("int8_weights+int8_cache", dict(deploy_bits=8, cache_bits=8)),
        ("int4_weights+int8_cache", dict(deploy_bits=4, cache_bits=8)),
    ],
    # Cell A (most collective-bound): MoE training.
    "mixtral_train": [
        ("baseline_cf1.25", dict()),
        ("capacity_factor_1.0", dict(overrides={
            "moe": None})),  # placeholder — replaced below
    ],
}

CELL_TARGETS = {
    "qwen2_decode": ("qwen2-0.5b", "decode_32k"),
    "minicpm_decode": ("minicpm-2b", "decode_32k"),
    "granite_decode": ("granite-3-8b", "decode_32k"),
    "mixtral_train": ("mixtral-8x22b", "train_4k"),
}


def mixtral_variants():
    # NOTE: "baseline" in EXPERIMENTS.md §Perf is the recorded sweep row
    # (pre-A1 sharding rules). Every lowering below includes A1 (vocab-TP
    # embed/unembed — a global rule fix).
    from repro.configs.base import MoEConfig
    rs = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                   combine="reduce_scatter")
    cf1 = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.0)
    return [
        ("A1_vocab_tp+sharded_ce", dict()),
        ("A2_rs_combine(refuted)", dict(overrides={"moe": rs})),
        ("A1+A3_cf1.0", dict(overrides={"moe": cf1})),
        ("A1+A3+A5_dots_saveable", dict(overrides={
            "moe": cf1, "remat": "dots_saveable"})),
    ]


def main():
    import jax
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    arch, shape = CELL_TARGETS[args.cell]
    variants = mixtral_variants() if args.cell == "mixtral_train" \
        else CELLS[args.cell]
    rows = []
    for label, kw in variants:
        print(f"=== {args.cell}: {label} ===", flush=True)
        kw = dict(kw)
        mesh_v = mesh
        if "mesh" in kw:   # serving-topology variant (e.g. B3)
            shp = kw.pop("mesh")
            mesh_v = jax.make_mesh(shp, ("data", "model"))
        try:
            row = lower_variant(arch, shape, mesh_v, label=label, **kw)
        except Exception as e:
            import traceback
            traceback.print_exc()
            row = {"variant": label, "error": str(e)}
        rows.append(row)
        print({k: row.get(k) for k in ("variant", "dominant", "step_s",
                                       "compute_s", "memory_s",
                                       "collective_s")}, flush=True)
    out = f"artifacts/perf_{args.cell}.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print("wrote", out)


if __name__ == "__main__":
    main()
