"""Paper Fig. 6 (sensitivity curves) + Table 2 / Fig. 7 (ablation:
joint search with the sensitivity features disabled)."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import get_lm_testbed
from benchmarks.search_setup import lm_search
from repro.core.compress import CompressibleLM
from repro.core.sensitivity import full_sweep


def sensitivity_curves(verbose=True):
    """Fig. 6: KL distortion per layer for quant-w / quant-a / prune."""
    cfg, params, val, _ = get_lm_testbed()
    cm = CompressibleLM(cfg, params)
    rows = full_sweep(cm, val, w_bits=(8, 4, 2), a_bits=(8, 4, 2),
                      n_prune=5)
    if verbose:
        # later layers should be more sensitive on average (paper Fig. 6)
        by_layer = {}
        for r in rows:
            if r["method"] == "quant_w" and r["param"] == 2:
                by_layer[r["layer"]] = r["kl"]
        print("[fig6] per-layer KL at w=2bit:",
              {k: round(v, 3) for k, v in list(by_layer.items())[:8]},
              flush=True)
    return rows


def ablation(c=0.35, verbose=True):
    """Table 2: joint search, sensitivity enabled vs disabled."""
    out = []
    for enabled in (True, False):
        search = lm_search("pq", c, seed=3, sens_enabled=enabled)
        res = search.run(verbose=False)
        best = res.best_under_budget(0.05) or res.best
        # action heterogeneity: std of kept-fractions + bits across layers
        keeps, bits = [], []
        for s, cmp in zip(search.specs, best.policy.cmps):
            if s.prunable and s.prune_dim:
                keeps.append(cmp.keep / s.prune_dim)
            if s.quantizable:
                bits.append(cmp.w_bits)
        out.append({
            "table": "table2", "sensitivity": enabled,
            "accuracy": round(best.accuracy, 4),
            "macs_frac": round(best.macs_frac, 4),
            "latency_frac": round(best.latency_s / res.ref_latency_s, 4),
            "keep_std": round(float(np.std(keeps)), 4),
            "bits_std": round(float(np.std(bits)), 4),
        })
        if verbose:
            r = out[-1]
            print(f"[table2] sens={enabled}: acc={r['accuracy']:.3f} "
                  f"macs={r['macs_frac']:.3f} keep_std={r['keep_std']:.3f} "
                  f"bits_std={r['bits_std']:.3f}", flush=True)
    return out


def main(out="artifacts/bench_sensitivity.json"):
    rows = {"curves": sensitivity_curves(), "ablation": ablation()}
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
