"""§Roofline table generator: reads the dry-run JSONs and renders the
per-(arch x shape x mesh) three-term roofline table (deliverable g).

Derived fields are RECOMPUTED here from the raw per-chip counts
(flops / bytes / collective_bytes / model_flops / chips) so the table is
independent of the code version that produced a JSON:

    compute_s    = flops_per_chip / peak(compute_dtype)
    memory_s     = bytes_per_chip / hbm_bw

``peak(compute_dtype)`` selects ``peak_int8`` for int8-dominant programs
(mirrors ``RooflineReport.compute_peak``); JSONs from before the field
existed default to bf16.
    collective_s = collective_bytes_per_chip / ici_bw
    step_s       = max(three terms)
    useful_ratio = model_flops / (flops_per_chip * chips)
    roofline_fraction = (model_flops / step_s) / (peak_bf16 * chips)

Known bias (EXPERIMENTS.md §Methodology): the chunked-attention inner scan
is cost-counted once, so compute_s is a floor for long-context attention
cells; step_s/dominant are unaffected (those cells are memory/collective
bound by >10x).
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.latency import V5E

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def derive(r: dict, hw=V5E) -> dict:
    if "skipped" in r or "error" in r:
        return r
    out = dict(r)
    peak = hw.peak_int8 if r.get("compute_dtype", "bf16") == "int8" \
        else hw.peak_bf16
    out["compute_s"] = r["flops"] / peak
    out["memory_s"] = r["bytes"] / hw.hbm_bw
    out["collective_s"] = r["collective_bytes"] / hw.ici_bw
    terms = {"compute": out["compute_s"], "memory": out["memory_s"],
             "collective": out["collective_s"]}
    out["dominant"] = max(terms, key=terms.get)
    out["step_s"] = max(terms.values())
    tot = r["flops"] * r["chips"]
    out["useful_flops_ratio"] = r["model_flops"] / tot if tot else 0.0
    out["roofline_fraction"] = ((r["model_flops"] / out["step_s"])
                                / (hw.peak_bf16 * r["chips"])
                                if out["step_s"] else 0.0)
    return out


def load(mesh: str = "singlepod") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(f) as fh:
            rows.append(derive(json.load(fh)))
    return rows


def render(rows, title="singlepod") -> str:
    out = [f"## Roofline — {title}",
           "| arch | shape | compute_s | memory_s | collective_s | dominant"
           " | step_s | MODEL_FLOPS | useful_ratio | roofline_frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped: {r['skipped']} | — | — | — | — |")
        elif "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — | — | — |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
                f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                f"{r['dominant']} | {r['step_s']:.2e} | "
                f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} | "
                f"{r['roofline_fraction']:.4f} |")
    return "\n".join(out)


def main(verbose=True):
    for mesh in ("singlepod", "multipod"):
        rows = load(mesh)
        if rows and verbose:
            print(render(rows, mesh))
            print()
    return {m: load(m) for m in ("singlepod", "multipod")}


if __name__ == "__main__":
    main()
