"""HLO collective profiler: list the largest collectives in a lowered cell
(per-op shapes + source metadata) — the 'profile' for §Perf hillclimbing.

  PYTHONPATH=src:. python benchmarks/hlo_analysis.py --arch mixtral-8x22b \
      --shape train_4k --layers 1 --top 15
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.launch.dryrun as DR
from repro.core.latency import _COLLECTIVE_RE, _first_shape_bytes
from repro.configs.base import SHAPES_BY_NAME
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_config


def top_collectives(hlo: str, top: int = 15):
    rows = []
    for line in hlo.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or " = " not in line or "-done" in line:
            continue
        b = _first_shape_bytes(line)
        meta = ""
        mm = re.search(r'op_name="([^"]+)"', line)
        if mm:
            meta = mm.group(1)[-90:]
        head = line.strip().split(" = ")[1][:60]
        rows.append((b, m.group(1), head, meta))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--deploy-bits", type=int, default=None)
    ap.add_argument("--cache-bits", type=int, default=16)
    args = ap.parse_args()
    mesh = make_production_mesh()
    cfg = get_config(args.arch)
    if SHAPES_BY_NAME[args.shape].mode == "train":
        cfg = cfg.replace(remat="full")
    cfg = cfg.replace(num_layers=args.layers, scan_layers=False)
    row, compiled = DR._lower(cfg, SHAPES_BY_NAME[args.shape], mesh,
                              deploy_bits=args.deploy_bits,
                              cache_bits=args.cache_bits)
    print(f"totals/dev: flops={row['flops']:.3e} bytes={row['bytes']:.3e} "
          f"coll={row['collective_bytes']:.3e}")
    for b, kind, head, meta in top_collectives(compiled.as_text(),
                                               args.top):
        print(f"{b / 1e9:9.3f} GB  {kind:18s} {head}")
        if meta:
            print(f"            {meta}")


if __name__ == "__main__":
    main()
