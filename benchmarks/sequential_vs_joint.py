"""Paper appendix (Fig. 5): sequential (prune->quant, quant->prune) vs
concurrent joint search at the same effective target rate.

Sequential scheme: first run with c1 = 0.5*(1-c)+c ... the paper uses
c1 = 0.5*(1+c)? — it states c_1 = 0.5·(1-c) with c=0.2 interpreted as a
*less aggressive* first stage (0.6 in Fig. 5a/b captions, i.e.
c1 = 1 - 0.5*(1-c)). We follow the figure captions: c1=0.6 then the
second search must reach the remaining factor c/c1."""
from __future__ import annotations

import copy
import json
import os

from benchmarks.search_setup import lm_search


def _frozen_steps(search, frozen_policy, frozen_methods):
    """Apply a previous policy's CMPs as the starting reference so the
    second-stage agent only controls its own method's parameters."""
    search.ref_policy = copy.deepcopy(frozen_policy)
    # re-derive reference latency from the frozen starting point
    from repro.core.latency import policy_latency
    search.ref_lat_frozen = policy_latency(search.specs, search.ref_policy,
                                           search.hw, search.ctx)
    return search


def sequential(first: str, second: str, c: float, c1: float, seed=4,
               verbose=True):
    s1 = lm_search(first, c1, seed=seed)
    r1 = s1.run(verbose=False)
    best1 = r1.best_under_budget(0.05) or r1.best

    s2 = lm_search(second, c, seed=seed + 1)
    s2 = _frozen_steps(s2, best1.policy, first)
    r2 = s2.run(verbose=False)
    best2 = r2.best_under_budget(0.05) or r2.best
    row = {
        "scheme": f"{first}->{second}",
        "stage1_latency_frac": round(best1.latency_s / r1.ref_latency_s, 4),
        "latency_frac": round(best2.latency_s / r2.ref_latency_s, 4),
        "accuracy": round(best2.accuracy, 4),
        "macs_frac": round(best2.macs_frac, 4),
        "bops": best2.bops,
    }
    if verbose:
        print(f"[fig5] {row['scheme']:8s} final lat={row['latency_frac']:.3f}"
              f" acc={row['accuracy']:.3f}", flush=True)
    return row


def run(c=0.35, c1=0.6, verbose=True):
    rows = [sequential("p", "q", c, c1, verbose=verbose),
            sequential("q", "p", c, c1, verbose=verbose)]
    sj = lm_search("pq", c, seed=6)
    rj = sj.run(verbose=False)
    bj = rj.best_under_budget(0.05) or rj.best
    rows.append({
        "scheme": "joint",
        "latency_frac": round(bj.latency_s / rj.ref_latency_s, 4),
        "accuracy": round(bj.accuracy, 4),
        "macs_frac": round(bj.macs_frac, 4),
        "bops": bj.bops,
    })
    if verbose:
        print(f"[fig5] joint    final lat={rows[-1]['latency_frac']:.3f}"
              f" acc={rows[-1]['accuracy']:.3f}", flush=True)
    return rows


def main(out="artifacts/bench_fig5.json"):
    rows = run()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
