"""Kernel micro-bench: CPU-interpret timings (plumbing check only — the
TPU roofline numbers come from the dry-run) + jnp-reference timings."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(verbose=True):
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512))
    w = jax.random.normal(key, (512, 256))
    rows.append(("kernel.quant_matmul_int8_cpu_interp",
                 _time(lambda: ops.quantized_matmul(x, w, 8)),
                 "256x512x256"))
    rows.append(("ref.f32_matmul", _time(lambda: (x @ w)), "256x512x256"))

    xx = jax.random.normal(key, (512, 256))
    rows.append(("kernel.fake_quant_cpu_interp",
                 _time(lambda: ops.fused_fake_quant(xx, 4)), "512x256 b4"))

    q = jax.random.normal(key, (1, 4, 256, 32))
    k = jax.random.normal(key, (1, 2, 256, 32))
    rows.append(("kernel.flash_attn_cpu_interp",
                 _time(lambda: ops.flash_attention(q, k, k)),
                 "S=256 H=4 D=32"))
    rows.append(("ref.attention", _time(
        lambda: ref.attention_ref(q, k, k)), "S=256 H=4 D=32"))

    a = jax.random.uniform(key, (2, 128, 128), minval=0.5, maxval=0.99)
    b = jax.random.normal(key, (2, 128, 128))
    rows.append(("kernel.rglru_scan_cpu_interp",
                 _time(lambda: ops.rglru_scan(a, b)), "B2 S128 C128"))

    xh = jax.random.normal(key, (1, 128, 4, 16))
    dA = -jax.random.uniform(key, (1, 128, 4), maxval=0.4)
    Bm = jax.random.normal(key, (1, 128, 16))
    rows.append(("kernel.ssd_scan_cpu_interp",
                 _time(lambda: ops.ssd_scan(xh, dA, Bm, Bm, chunk=32)),
                 "S128 H4 P16 N16"))
    if verbose:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
