"""Paper Fig. 4: vary the target compression rate c and check that each
agent's found policy lands on the latency budget (reward-only control)."""
from __future__ import annotations

import json
import os

from benchmarks.search_setup import lm_search

CS_FULL = (0.25, 0.3, 0.4, 0.5, 0.6, 0.7)
CS_FAST = (0.3, 0.5, 0.7)


def run(cs=None, agents=("p", "q", "pq"), verbose=True):
    import benchmarks.search_setup as S
    cs = cs or (CS_FULL if S.FULL else CS_FAST)
    rows = []
    labels = {"p": "pruning", "q": "quantization", "pq": "joint"}
    for c in cs:
        for m in agents:
            search = lm_search(m, c, seed=2)
            res = search.run(verbose=False)
            best = res.best_under_budget(0.05) or res.best
            rows.append({
                "table": "fig4", "agent": labels[m], "c": c,
                "achieved_latency_frac": round(
                    best.latency_s / res.ref_latency_s, 4),
                "on_budget": bool(best.latency_ratio <= 1.05),
                "accuracy": round(best.accuracy, 4),
                "ref_accuracy": round(res.ref_accuracy, 4),
            })
            if verbose:
                r = rows[-1]
                print(f"[fig4] {labels[m]:12s} c={c:.2f} -> achieved "
                      f"{r['achieved_latency_frac']:.3f} "
                      f"acc={r['accuracy']:.3f} on_budget={r['on_budget']}",
                      flush=True)
    return rows


def main(out="artifacts/bench_fig4.json"):
    rows = run()
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
