"""Calibrate the analytic latency oracle against executed deploy-path
kernels (the measured-latency artifact generator).

Pipeline, mirroring the paper's compile-and-measure loop:

1. per-unit deploy-path measurements (``measure_unit_rows``) — every
   layer-spec shape in each weight container, timed against its analytic
   roofline term;
2. informational Pallas ``quant_matmul`` kernel rows;
3. whole-model deployed-forward measurements for uniform raw / int8 /
   int4 policies, with ``roofline_from_compiled`` cost extraction;
4. ``fit_calibration`` (per-kind geometric-mean ratios) +
   ``fit_extra_factor`` (attention/overhead residual from the raw row);
5. end-to-end demo: for the uniform int8/int4 policies, the calibrated
   oracle's predicted latency ratio vs raw is compared to the measured
   wall-clock ratio — ``within_tol`` is the acceptance flag.

The output JSON (default ``artifacts/latency_calibration.json``) embeds
the full evidence (units / kernels / model / demo) alongside the
``ratios``/``extra``/``meta`` keys that ``CalibrationTable.load`` reads.

Interpretation caveat: factors are host-specific. On CPU the int8/int4
containers are typically SLOWER than raw (dequantize-into-matmul
overhead, no integer MXU), i.e. ratios > the raw ratio — exactly the
proxy-vs-measured gap the paper's measured oracle exists to catch. The
regression gate therefore compares ratios normalized by the raw
container (box speed cancels), not absolute values.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks.common import ART, get_lm_testbed
from repro.core.compress import CompressibleLM
from repro.core.latency import (CONTAINERS, LatencyContext, V5E,
                                policy_latency)
from repro.core.measure import (MeasureConfig, fit_calibration,
                                fit_extra_factor, measure_kernel_rows,
                                measure_model_row, measure_unit_rows,
                                uniform_policy)
from repro.core.policy import Policy

DEFAULT_OUT = os.path.join(ART, "latency_calibration.json")

# demo acceptance: |predicted_ratio - measured_ratio| <= TOL * measured
DEMO_TOL = 0.35


def run(out_path: str = DEFAULT_OUT, warmup: int = 2, repeats: int = 5,
        verbose: bool = True) -> dict:
    cfg, params, val, _ = get_lm_testbed()
    cm = CompressibleLM(cfg, params)
    toks = val["tokens"][:4]
    B, S = toks.shape
    batch = {"tokens": toks}
    # prefill context matching the measured forward: B sequences of S
    # tokens in one dispatch
    mctx = LatencyContext(tokens=B * S, seq_ctx=S, mode="prefill", batch=B)
    mcfg = MeasureConfig(warmup=warmup, repeats=repeats, tokens=B * S)

    if verbose:
        print(f"# measuring units ({len(cm.specs)} specs x "
              f"{len(CONTAINERS)} containers, deduped) ...")
    unit_rows = measure_unit_rows(cm.specs, V5E, mctx, mcfg)
    kernel_rows = measure_kernel_rows(mcfg)

    if verbose:
        print("# measuring whole-model deployed forwards ...")
    model_rows = {c: measure_model_row(cm, batch, c, mcfg)
                  for c in CONTAINERS}

    meta = {
        "model": cfg.name,
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "ctx": {"tokens": B * S, "seq_ctx": S, "mode": "prefill",
                "batch": B},
        "note": ("factors are host-specific; on CPU integer containers "
                 "are slower than raw (dequant overhead) — compare "
                 "ratios normalized by the raw container"),
    }
    table = fit_calibration(unit_rows, meta=meta)
    ref = Policy.reference(cm.specs)
    fit_extra_factor(table, cm.specs, ref,
                     model_rows["raw"]["measured_s"], V5E, mctx)

    # --- end-to-end demo: calibrated prediction vs measured wall clock ---
    ref_pred = policy_latency(cm.specs, ref, V5E, mctx, calib=table).total_s
    raw_meas = model_rows["raw"]["measured_s"]
    demo = []
    for c in ("int8", "int4"):
        pol = uniform_policy(cm.specs, c)
        pred = policy_latency(cm.specs, pol, V5E, mctx, calib=table).total_s
        pr = pred / ref_pred
        mr = model_rows[c]["measured_s"] / raw_meas
        demo.append({"container": c, "predicted_s": pred,
                     "predicted_ratio": pr, "measured_ratio": mr,
                     "tolerance": DEMO_TOL,
                     "within_tol": abs(pr - mr) <= DEMO_TOL * mr})

    out = {"meta": meta, "ratios": table.ratios, "extra": table.extra,
           "units": unit_rows, "kernels": kernel_rows,
           "model": model_rows, "demo": demo}
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
    if verbose:
        print(f"# wrote {out_path}")
        for k, d in sorted(table.ratios.items()):
            facs = " ".join(f"{c}={v:.3g}" for c, v in sorted(d.items()))
            print(f"  ratio {k:10s} {facs}")
        print(f"  extra attn/overhead = {table.extra_factor():.3g}")
        for r in demo:
            print(f"  demo {r['container']}: predicted_ratio="
                  f"{r['predicted_ratio']:.3f} measured_ratio="
                  f"{r['measured_ratio']:.3f} within_tol={r['within_tol']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    a = ap.parse_args(argv)
    out = run(a.out, a.warmup, a.repeats)
    bad = [r for r in out["demo"] if not r["within_tol"]]
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
